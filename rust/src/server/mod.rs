//! The XUFS user-space file server (paper §3.1–3.2), as a **namespace-
//! sharded concurrent core** (DESIGN.md §2.6).
//!
//! Runs on (or beside) the user's personal system, exporting the home
//! space to client sites. Transport-agnostic: [`FileServer::handle`] maps
//! one authenticated request to one response and takes `&self`, so any
//! number of connection threads (the TCP deployment) or interleaved
//! simulated clients (the sim deployment) dispatch concurrently without a
//! global lock.
//!
//! Concurrency model (DESIGN.md §2.6):
//!
//! * Per-path service state — digest cache, lock table, replay
//!   watermarks, callback registry — splits into N **shards**, each
//!   behind its own mutex, routed by canonical-path hash. Requests for
//!   different subtrees proceed in parallel; requests for the same path
//!   always serialize through the same shard.
//! * The inode substrate ([`FileStore`]) sits behind one `RwLock`:
//!   namespace reads run in parallel under the read lock, mutations take
//!   brief write sections.
//! * Block reads and digest computation run **outside any shard lock**,
//!   so bulk fetches from different clients overlap even within a shard.
//! * Cross-shard operations (a rename whose source and target hash to
//!   different shards, registry broadcasts) take their shard locks in
//!   ascending index order — the single lock-ordering rule that keeps the
//!   core deadlock-free.
//! * The per-client idempotent-replay watermark lives in the shard of the
//!   op's primary path. A given `(client, seq)` always routes to the same
//!   shard, so duplicate detection is exactly as strong as under the old
//!   global lock (DESIGN.md §2.5 invariants hold unchanged).
//!
//! Responsibilities (unchanged from the paper):
//! * serve namespace reads (stat/readdir) and whole-file/range fetches
//!   with per-block digests for integrity + later delta writeback;
//! * apply replayed meta-operations **idempotently** (per-client sequence
//!   numbers — a crashed client can replay its whole queue safely);
//! * fan out change notifications to registered callback channels
//!   (skipping the originating client, whose copy is already current);
//! * grant lock leases via [`LockTable`] and expire orphans;
//! * simulate crash/restart (the paper restarts the server from crontab);
//! * as one half of a replicated pair (DESIGN.md §2.7): record applied
//!   ops in a durable replication log ([`Role::Primary`]), or ingest the
//!   shipped log through the same apply path ([`Role::Secondary`]) so
//!   idempotence watermarks, failed-seq sets and conflict preservation
//!   replicate by construction — and take over on an explicit
//!   [`Request::Promote`];
//! * run the home space over the content-addressed chunk store
//!   (DESIGN.md §2.8, `[chunkstore]`): cross-user dedup, O(1)-data CoW
//!   snapshots with `@vN` read-only views, write payloads spilled into
//!   the replication log by reference (`MetaOp::WriteRef`, with
//!   `ChunkPush` filling the secondary's gaps) and acked-prefix log
//!   truncation.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

use crate::callback::NotifyChannel;
use crate::chunkstore::{digest_hex, Digest};
use crate::config::{ChunkstoreConfig, IntegrityConfig};
use crate::homefs::{FileStore, FsError, NodeKind};
use crate::lease::{Acquire, LockTable};
use crate::metrics::{names, Metrics};
use crate::proto::{
    BlockExtent, CompoundOp, DirEntry, FileImage, MetaOp, NotifyEvent, ReplPayload, ReplRecord,
    Request, Response, WireAttr,
};
use crate::runtime::DigestEngine;
use crate::simnet::VirtualTime;
use crate::transfer;
use crate::util::path as vpath;
use crate::vdisk::DiskModel;

/// The server's place in a replicated pair (DESIGN.md §2.7). A plain
/// unreplicated deployment runs a lone `Primary`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Serves clients; records applied ops in the replication log.
    Primary,
    /// Warm standby: ingests shipped log records through the normal
    /// apply path, refuses client requests (code 112) until promoted.
    Secondary,
    /// Fenced ex-primary: after a promotion the old node, even once its
    /// process restarts, refuses everything but `Ping`/`WatermarkQuery`
    /// so a stale client cannot split-brain the namespace.
    Retired,
}

const ROLE_PRIMARY: u8 = 0;
const ROLE_SECONDARY: u8 = 1;
const ROLE_RETIRED: u8 = 2;

/// The applied-op replication log (DESIGN.md §2.7). On the primary it is
/// the ship source (journaled to the home disk alongside the idempotence
/// watermarks — it survives `crash`); on the secondary it is the mirror
/// that makes ship-seqs line up across the pair and per-shard watermarks
/// answerable.
///
/// Retention (DESIGN.md §2.8): write payloads are spilled by reference
/// (`MetaOp::WriteRef` digest lists pinning chunks in the §2.8 chunk
/// store), and the prefix the secondary has ACKED is truncated away —
/// `base` is the ship-seq of the last truncated record, and the folded
/// per-path summary keeps the fault explorer's I4 oracle exact without
/// replaying dropped records.
#[derive(Debug, Default)]
struct ReplLog {
    /// Ship-seq of the last truncated record: `records[i].ship_seq ==
    /// base + i + 1` and the global watermark is `base + records.len()`.
    base: u64,
    records: Vec<ReplRecord>,
    /// Per-shard watermark: ship-seq of the latest record routed to each
    /// namespace shard (`Request::WatermarkQuery { shard }`).
    shard_watermarks: Vec<u64>,
    /// Folded last effect per path over the truncated prefix, exactly as
    /// the I4 oracle would have computed it: `Some(v)` = the prefix left
    /// the path existing at version `v`, `None` = it left it removed.
    truncated_effects: BTreeMap<String, Option<u64>>,
    /// Paths touched by truncated `Local` records (version-untracked —
    /// the oracle skips them, so the skip set must survive truncation).
    truncated_local: BTreeSet<String>,
}

impl ReplLog {
    /// Global watermark: ship-seq of the last record ever appended.
    fn ship_seq(&self) -> u64 {
        self.base + self.records.len() as u64
    }

    /// Fold one truncated record into the retained summary (the same
    /// per-path last-effect rule the I4 oracle applies to live records).
    fn fold_truncated(&mut self, rec: &ReplRecord) {
        match &rec.payload {
            ReplPayload::Op { new_version, op, .. } => match op {
                MetaOp::Rename { from, to } => {
                    self.truncated_effects.insert(from.clone(), None);
                    self.truncated_effects.insert(to.clone(), Some(*new_version));
                }
                MetaOp::Unlink { path } | MetaOp::Rmdir { path } => {
                    self.truncated_effects.insert(path.clone(), None);
                }
                _ => {
                    self.truncated_effects.insert(op.path().to_string(), Some(*new_version));
                }
            },
            ReplPayload::Local { op } => {
                self.truncated_local.insert(op.path().to_string());
            }
            ReplPayload::Failed { .. } => {}
        }
    }
}

/// One registered callback (client + subtree root + channel).
#[derive(Debug)]
struct CallbackReg {
    client_id: u64,
    root: String,
    channel: NotifyChannel,
}

/// Per-path service state owned by one namespace shard (DESIGN.md §2.6).
/// Everything here is only ever touched under the shard's mutex.
struct Shard {
    /// Digest cache: path -> (version, digests). Fetches of unchanged
    /// files skip recomputation (hot-path optimization, EXPERIMENTS §Perf).
    digest_cache: HashMap<String, (u64, Vec<i32>)>,
    /// Lock leases for paths routed to this shard. Tokens come from a
    /// per-shard arithmetic progression so a bare renew/release token
    /// routes back here (`LockTable::with_tokens`).
    locks: LockTable,
    /// Callback registrations, **replicated** to every shard: the
    /// registry is tiny and write-rare, and replication lets a mutating
    /// op fan out invalidations without leaving its own shard lock.
    /// Updated only under the ordered all-shard lock path.
    callbacks: Vec<CallbackReg>,
    /// Highest applied meta-op sequence per client, for ops routed to
    /// this shard (idempotent replay). A `(client, seq)` pair always
    /// routes to the same shard, so the per-shard watermark answers
    /// duplicates exactly like the old global one. Journaled to the home
    /// disk (survives `crash`).
    applied: HashMap<u64, u64>,
    /// Seqs at or below the watermark that failed SEMANTICALLY, per
    /// client. A compound advances the watermark past a mid-batch
    /// failure (later ops in the frame still apply), so after a lost
    /// reply the replay of the failed seq must be retried for real —
    /// answering it as a duplicate would falsely ack a write that never
    /// landed. Bounded per client (oldest evicted).
    failed: HashMap<u64, BTreeSet<u64>>,
    /// Bumped on every digest-cache purge. The unlocked fetch-path
    /// digest pass records this before snapshotting and refuses to
    /// install if it moved — otherwise a rename that preserves the
    /// moved inode's version could race an in-flight digest pass and
    /// have the old content's digests re-installed under a version that
    /// now identifies the new content.
    purge_epoch: u64,
}

impl Shard {
    /// Drop a path's cached digests and advance the purge epoch (see
    /// [`Shard::purge_epoch`]). Every invalidation-class removal goes
    /// through here; plain version-keyed inserts do not.
    fn purge_digests(&mut self, key: &str) {
        self.digest_cache.remove(key);
        self.purge_epoch += 1;
    }

    /// Replayed-duplicate test (DESIGN.md §2.5): seq at or below this
    /// client's watermark and not recorded as a semantic failure.
    fn is_duplicate(&self, client_id: u64, seq: u64) -> bool {
        let last = self.applied.get(&client_id).copied().unwrap_or(0);
        let failed = self.failed.get(&client_id).map(|s| s.contains(&seq)).unwrap_or(false);
        seq <= last && !failed
    }
}

/// The user-space file server. All methods take `&self`: share it as
/// `Arc<FileServer>` across connection threads or simulated links.
pub struct FileServer {
    fs: RwLock<FileStore>,
    pub disk: DiskModel,
    engine: Arc<DigestEngine>,
    block_bytes: usize,
    lease_s: f64,
    shards: Vec<Mutex<Shard>>,
    /// Callback channel per client (attached by the transport at
    /// connect). One copy behind its own leaf mutex — unlike the
    /// `callbacks` registry it is not consulted on the fanout hot path,
    /// so it needs no replication. Never locked while a shard guard is
    /// held.
    channel_map: Mutex<HashMap<u64, NotifyChannel>>,
    up: AtomicBool,
    /// When set, modeled disk service times are slept for REAL (the
    /// wall-clock scale bench; the analytic deployments leave this off
    /// and charge the virtual clock instead).
    modeled_waits: AtomicBool,
    /// Replica-pair role ([`Role`]); survives `crash` like the rest of
    /// the durable identity (a fenced Retired node restarts fenced).
    role: AtomicU8,
    /// Applied-op logging is opt-in (`[replica] enabled`): an
    /// unreplicated deployment must not accumulate write payloads.
    repl_enabled: AtomicBool,
    /// Read fan-out (DESIGN.md §2.11): when set, a `Secondary` serves
    /// read-only traffic at its replication watermark instead of
    /// refusing everything outside the replication plane.
    read_serving: AtomicBool,
    /// Bounded-staleness window for a serving secondary
    /// (`replica.staleness_ops`): reads are refused with code 119 when
    /// this node's watermark trails [`Self::known_repl_head`] by more.
    staleness_limit: AtomicU64,
    /// The primary's log head as last announced by a `Replicate` batch
    /// — the serving secondary's only view of how far behind it is.
    known_head: AtomicU64,
    /// The applied-op log. Lock ordering: a shard guard may be held when
    /// this is taken (apply-time append), never the reverse.
    repl: Mutex<ReplLog>,
    /// Serializes whole-record ingestion on the secondary (gap check +
    /// apply + mirror must be atomic against concurrent `Replicate`s).
    /// Ordering: taken before any shard guard, never while one is held.
    repl_ingest: Mutex<()>,
    /// `[chunkstore]` knobs this server was stood up with (DESIGN.md
    /// §2.8). When enabled, the home `FileStore` runs over the content-
    /// addressed chunk store and write payloads ship by reference.
    chunk_cfg: ChunkstoreConfig,
    /// Mutations since the last dead-chunk sweep (the deferred-GC
    /// cadence: sweep every `chunkstore.gc_interval_ops` applied ops).
    ops_since_gc: AtomicU64,
    /// `[integrity]` knobs (DESIGN.md §2.10): cadence and slice width
    /// of the background digest scrub over the chunk table.
    integrity: IntegrityConfig,
    /// Requests handled since the last scrub slice (cadence counter,
    /// same shape as the GC's).
    ops_since_scrub: AtomicU64,
    /// Resume point of the scrub walk over the sorted chunk table.
    /// The table mutates between slices — the walk is amortized
    /// coverage, not an exact iteration, and wraps at the end.
    scrub_cursor: AtomicU64,
    /// Transfer pins held by `ChunkPush` (secondary only): one entry per
    /// pushed chunk, released wholesale once a `Replicate` batch lands
    /// (by then file/snapshot/log residency owns its own refs). Leaf
    /// mutex: taken after the `fs` lock, never before it.
    staged_chunks: Mutex<Vec<Digest>>,
    metrics: Metrics,
}

impl std::fmt::Debug for FileServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FileServer")
            .field("up", &self.is_up())
            .field("shards", &self.shards.len())
            .finish()
    }
}

fn err_resp(e: &FsError) -> Response {
    let code = match e {
        FsError::NotFound(_) => 2,
        FsError::NotADir(_) => 20,
        FsError::IsADir(_) => 21,
        FsError::Exists(_) => 17,
        FsError::NotEmpty(_) => 39,
        FsError::NoSpace => 28,
        FsError::Stale(_) => 116,
        // integrity refusal (DESIGN.md §2.10): the bytes on disk no
        // longer match their recorded digest and are NOT served
        FsError::Corrupted(_) => 118,
        _ => 5,
    };
    Response::Err { code, msg: e.to_string() }
}

/// FNV-1a — stable, dependency-free canonical-path hash for shard routing.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl FileServer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        mut fs: FileStore,
        disk: DiskModel,
        engine: Arc<DigestEngine>,
        block_bytes: usize,
        lease_s: f64,
        shards: usize,
        metrics: Metrics,
        chunk_cfg: ChunkstoreConfig,
    ) -> Self {
        if chunk_cfg.enabled {
            // flip the home space onto the content-addressed substrate
            // (idempotent: a pre-populated dense image converts in place)
            fs.enable_chunking(
                chunk_cfg.chunk_kib.max(1) * 1024,
                chunk_cfg.snapshot_retention.max(1),
            );
            fs.attach_metrics(&metrics);
        }
        let n = shards.max(1);
        let shards = (0..n)
            .map(|i| {
                Mutex::new(Shard {
                    digest_cache: HashMap::new(),
                    locks: LockTable::with_tokens(lease_s, i as u64 + 1, n as u64),
                    callbacks: Vec::new(),
                    applied: HashMap::new(),
                    failed: HashMap::new(),
                    purge_epoch: 0,
                })
            })
            .collect();
        FileServer {
            fs: RwLock::new(fs),
            disk,
            engine,
            block_bytes,
            lease_s,
            shards,
            channel_map: Mutex::new(HashMap::new()),
            up: AtomicBool::new(true),
            modeled_waits: AtomicBool::new(false),
            role: AtomicU8::new(ROLE_PRIMARY),
            repl_enabled: AtomicBool::new(false),
            read_serving: AtomicBool::new(false),
            staleness_limit: AtomicU64::new(64),
            known_head: AtomicU64::new(0),
            repl: Mutex::new(ReplLog {
                shard_watermarks: vec![0; n],
                ..ReplLog::default()
            }),
            repl_ingest: Mutex::new(()),
            chunk_cfg,
            ops_since_gc: AtomicU64::new(0),
            integrity: IntegrityConfig::default(),
            ops_since_scrub: AtomicU64::new(0),
            scrub_cursor: AtomicU64::new(0),
            staged_chunks: Mutex::new(Vec::new()),
            metrics,
        }
    }

    // ---------------------------------------------------------------
    // replication: roles + the applied-op log (DESIGN.md §2.7)
    // ---------------------------------------------------------------

    pub fn role(&self) -> Role {
        match self.role.load(Ordering::SeqCst) {
            ROLE_SECONDARY => Role::Secondary,
            ROLE_RETIRED => Role::Retired,
            _ => Role::Primary,
        }
    }

    pub fn set_role(&self, role: Role) {
        let v = match role {
            Role::Primary => ROLE_PRIMARY,
            Role::Secondary => ROLE_SECONDARY,
            Role::Retired => ROLE_RETIRED,
        };
        self.role.store(v, Ordering::SeqCst);
    }

    /// Fence this node out of the pair (the demotion half of a
    /// promotion — see [`Role::Retired`]).
    pub fn retire(&self) {
        self.set_role(Role::Retired);
    }

    /// Turn on applied-op logging (`[replica] enabled`). Both members of
    /// a pair enable it: the primary to feed the shipper, the secondary
    /// so its own post-promotion applies continue the same log.
    pub fn enable_replication(&self) {
        self.repl_enabled.store(true, Ordering::SeqCst);
    }

    pub fn replication_enabled(&self) -> bool {
        self.repl_enabled.load(Ordering::SeqCst)
    }

    /// Turn on read fan-out for this node when it is a `Secondary`
    /// (`replica.read_fanout`, DESIGN.md §2.11): read-only requests are
    /// served at the replication watermark, gated by the bounded-
    /// staleness window `staleness_ops` and per-request version floors.
    /// A later promotion simply stops consulting either gate (the
    /// primary is always the freshest copy).
    pub fn enable_read_serving(&self, staleness_ops: u64) {
        self.staleness_limit.store(staleness_ops, Ordering::SeqCst);
        self.read_serving.store(true, Ordering::SeqCst);
    }

    pub fn read_serving(&self) -> bool {
        self.read_serving.load(Ordering::SeqCst)
    }

    /// The primary's log head as last announced over the replication
    /// plane (max across `Replicate` batches; 0 until the first one).
    pub fn known_repl_head(&self) -> u64 {
        self.known_head.load(Ordering::SeqCst)
    }

    /// Code-119 `TooStale` refusal (DESIGN.md §2.11) — the read-fan-out
    /// sibling of code 112: "this replica cannot serve THIS read yet;
    /// fall back toward the primary, don't sever the session".
    fn too_stale(&self, msg: String) -> Response {
        self.metrics.incr(names::REPLICA_TOO_STALE);
        Response::Err { code: 119, msg }
    }

    /// Global position of the applied-op log (ship-seq of its last
    /// record, truncated prefix included). On the secondary this IS the
    /// global replication watermark: the mirror only grows by ingesting.
    pub fn repl_ship_seq(&self) -> u64 {
        self.repl.lock().unwrap().ship_seq()
    }

    /// Ship-seq of the last record dropped by acked-prefix truncation
    /// (0 until [`Self::repl_truncate_acked`] first fires).
    pub fn repl_base(&self) -> u64 {
        self.repl.lock().unwrap().base
    }

    /// The folded summary of the truncated log prefix: last effect per
    /// path (`Some(version)` = left existing, `None` = left removed),
    /// plus the paths truncated `Local` records touched. The fault
    /// explorer's I4 oracle seeds its replay with this so truncation
    /// never weakens (or falsifies) the invariant.
    pub fn repl_truncated_summary(&self) -> (BTreeMap<String, Option<u64>>, BTreeSet<String>) {
        let g = self.repl.lock().unwrap();
        (g.truncated_effects.clone(), g.truncated_local.clone())
    }

    /// Per-shard replication watermark; any out-of-range index (the
    /// `u32::MAX` convention) reads the global one.
    pub fn repl_watermark(&self, shard: usize) -> u64 {
        let g = self.repl.lock().unwrap();
        match g.shard_watermarks.get(shard) {
            Some(w) => *w,
            None => g.ship_seq(),
        }
    }

    /// Up to `max` log records strictly after ship-seq `from` — the
    /// shipper's read side (local disk, no WAN). `from` below the
    /// truncation base just starts at the oldest retained record (the
    /// shipper never needs those: truncation only drops ACKED records).
    pub fn repl_records_after(&self, from: u64, max: usize) -> Vec<ReplRecord> {
        let g = self.repl.lock().unwrap();
        let start = (from.saturating_sub(g.base) as usize).min(g.records.len());
        let end = start.saturating_add(max).min(g.records.len());
        g.records[start..end].to_vec()
    }

    /// Drop the log prefix the secondary has durably ACKED (DESIGN.md
    /// §2.8): everything at or below `acked` is folded into the retained
    /// I4 summary and its `WriteRef` chunk pins are released. Returns
    /// the number of records truncated. Safe to call with a stale or
    /// over-long watermark — it clamps to what the log actually holds.
    pub fn repl_truncate_acked(&self, acked: u64) -> u64 {
        let (drained, n) = {
            let mut g = self.repl.lock().unwrap();
            let upto = acked.min(g.ship_seq());
            if upto <= g.base {
                return 0;
            }
            let n = (upto - g.base) as usize;
            let drained: Vec<ReplRecord> = g.records.drain(..n).collect();
            g.base = upto;
            for rec in &drained {
                g.fold_truncated(rec);
            }
            (drained, n as u64)
        };
        // release the truncated records' chunk pins OUTSIDE the log lock
        // (fs-then-repl is the only ordering the apply path ever uses)
        let mut fs = self.fs.write().unwrap();
        for rec in &drained {
            let op = match &rec.payload {
                ReplPayload::Op { op, .. } | ReplPayload::Local { op } => op,
                ReplPayload::Failed { .. } => continue,
            };
            if let MetaOp::WriteRef { chunks, .. } = op {
                for d in chunks {
                    fs.decref_chunk(d);
                }
            }
        }
        drop(fs);
        self.metrics.add(names::REPLICA_LOG_TRUNCATED, n);
        n
    }

    /// Append one record to the applied-op log (apply-time, shard guard
    /// held; see the `repl` field's lock-ordering note). On a chunked
    /// store, `WriteFull` payloads are spilled by reference first.
    fn log_record(&self, shard_idx: usize, payload: ReplPayload) {
        let payload = self.spill_payload(payload);
        let mut g = self.repl.lock().unwrap();
        let ship_seq = g.ship_seq() + 1;
        if let Some(w) = g.shard_watermarks.get_mut(shard_idx) {
            *w = ship_seq;
        }
        g.records.push(ReplRecord { ship_seq, shard: shard_idx as u32, payload });
    }

    /// Replication by reference (DESIGN.md §2.8): on a chunked store a
    /// `WriteFull` log payload is rewritten as a `WriteRef` — the file's
    /// chunk digest list instead of its bytes — with one refcount pin
    /// taken per chunk so GC can never collect content an un-truncated
    /// log record still names. The op's original `digests`/`base_version`
    /// ride along verbatim: the secondary materializes the record back
    /// into a `WriteFull` and re-runs the IDENTICAL conflict logic.
    /// Called with the path's shard guard held (so the just-written
    /// file's chunk list is exactly the logged payload).
    fn spill_payload(&self, payload: ReplPayload) -> ReplPayload {
        let is_write_full = matches!(
            &payload,
            ReplPayload::Op { op: MetaOp::WriteFull { .. }, .. }
                | ReplPayload::Local { op: MetaOp::WriteFull { .. } }
        );
        if !is_write_full {
            return payload;
        }
        let mut fs = self.fs.write().unwrap();
        if !fs.is_chunked() {
            return payload;
        }
        let spill = |fs: &mut FileStore, op: MetaOp| -> MetaOp {
            let MetaOp::WriteFull { path, data, digests, base_version } = op else {
                unreachable!("guarded above");
            };
            match fs.file_chunks(&path) {
                Ok((size, chunks)) => {
                    for d in &chunks {
                        fs.incref_chunk(d);
                    }
                    MetaOp::WriteRef { path, size, chunks, digests, base_version }
                }
                // racing unlink or a dense holdout: keep the bytes
                Err(_) => MetaOp::WriteFull { path, data, digests, base_version },
            }
        };
        match payload {
            ReplPayload::Op { client_id, seq, new_version, op } => {
                let op = spill(&mut fs, op);
                ReplPayload::Op { client_id, seq, new_version, op }
            }
            ReplPayload::Local { op } => ReplPayload::Local { op: spill(&mut fs, op) },
            other => other,
        }
    }

    /// Chunk bytes for a digest list — the shipper's read side when the
    /// secondary answers [`Response::ReplicaNeed`] (local disk, no WAN).
    /// Unknown digests are skipped; log pins make that unreachable for
    /// any digest a retained `WriteRef` record names.
    pub fn read_chunks(&self, digests: &[Digest]) -> Vec<Vec<u8>> {
        let fs = self.fs.read().unwrap();
        digests.iter().filter_map(|d| fs.chunk_data(d)).collect()
    }

    /// Materialize a shipped `WriteRef` back into the `WriteFull` it was
    /// spilled from, assembling the bytes from the local chunk store
    /// (the `Replicate` pre-scan guarantees residency; a miss here is a
    /// real protocol error). Non-ref ops pass through untouched.
    fn materialize_op(&self, op: MetaOp) -> Result<MetaOp, FsError> {
        match op {
            MetaOp::WriteRef { path, size, chunks, digests, base_version } => {
                let data = self.assemble_chunks(&chunks, size)?;
                Ok(MetaOp::WriteFull { path, data, digests, base_version })
            }
            other => Ok(other),
        }
    }

    fn assemble_chunks(&self, chunks: &[Digest], size: u64) -> Result<Vec<u8>, FsError> {
        let fs = self.fs.read().unwrap();
        let mut out = Vec::with_capacity(size as usize);
        for d in chunks {
            match fs.chunk_data(d) {
                Some(b) => out.extend_from_slice(&b),
                None => {
                    return Err(FsError::Protocol(format!(
                        "shipped WriteRef names unknown chunk {}",
                        digest_hex(d)
                    )))
                }
            }
        }
        if out.len() as u64 != size {
            return Err(FsError::Protocol(format!(
                "shipped WriteRef assembled {} bytes, manifest says {size}",
                out.len()
            )));
        }
        Ok(out)
    }

    /// Deferred dead-chunk sweep: every `chunkstore.gc_interval_ops`
    /// applied mutations (no-op on a dense store).
    fn maybe_gc(&self) {
        let interval = self.chunk_cfg.gc_interval_ops.max(1);
        let n = self.ops_since_gc.fetch_add(1, Ordering::Relaxed) + 1;
        if self.chunk_cfg.enabled && n % interval == 0 {
            self.fs.write().unwrap().gc();
        }
    }

    // ---------------------------------------------------------------
    // integrity plane (DESIGN.md §2.10)
    // ---------------------------------------------------------------

    /// Configure the background integrity scrub (`[integrity]` in
    /// `xufs.toml`). Builder-style, applied before the server is
    /// shared; the default cadence is [`IntegrityConfig::default`].
    pub fn with_integrity(mut self, cfg: IntegrityConfig) -> Self {
        self.integrity = cfg;
        self
    }

    /// Background digest scrub: every `integrity.scrub_interval_ops`
    /// handled requests, re-digest a bounded slice of the chunk table
    /// (`integrity.scrub_batch` entries) and quarantine mismatches —
    /// so bit rot is found proactively, not only when a client reads
    /// the rotted chunk. `scrub_interval_ops = 0` disables the walk.
    fn maybe_scrub(&self) {
        let interval = self.integrity.scrub_interval_ops;
        if !self.chunk_cfg.enabled || interval == 0 {
            return;
        }
        let n = self.ops_since_scrub.fetch_add(1, Ordering::Relaxed) + 1;
        if n % interval != 0 {
            return;
        }
        let cursor = self.scrub_cursor.load(Ordering::Relaxed) as usize;
        let batch = self.integrity.scrub_batch.max(1);
        let (next, _bad) = self.fs.write().unwrap().scrub_chunks(cursor, batch);
        self.scrub_cursor.store(next as u64, Ordering::Relaxed);
        self.metrics.incr(names::INTEGRITY_SCRUB_TICKS);
    }

    /// Walk the ENTIRE chunk table once, quarantining every mismatch.
    /// Returns the digests quarantined by this pass (repair drivers and
    /// the fault explorer call this; the op-cadence scrub covers the
    /// same ground a slice at a time).
    pub fn scrub_all_chunks(&self) -> Vec<Digest> {
        let mut fs = self.fs.write().unwrap();
        let n = fs.chunk_digests().len();
        let (_, bad) = fs.scrub_chunks(0, n.max(1));
        bad
    }

    /// Digests currently quarantined (detected corrupt, refused on
    /// reads, awaiting a replica fill).
    pub fn quarantined_chunks(&self) -> Vec<Digest> {
        self.fs.read().unwrap().quarantined_chunks()
    }

    /// Heal quarantined chunks from digest-verified replica fills (the
    /// bytes a [`Request::ChunkFetch`] round trip produced). Each fill
    /// is re-digested locally; bytes that do not match a quarantined
    /// digest are dropped — a rotted or forged fill cannot land.
    /// Returns how many chunks were repaired.
    pub fn repair_chunks(&self, fills: &[Vec<u8>]) -> u64 {
        let mut fs = self.fs.write().unwrap();
        fills.iter().filter(|b| fs.repair_chunk(b).is_some()).count() as u64
    }

    /// Ingest one shipped record on the secondary: strict gapless order
    /// (`watermark + 1` applies, at-or-below skips as an idempotent
    /// re-ship, beyond refuses), replayed through the NORMAL apply path
    /// so watermarks/failure-sets/conflict-preservation replicate by
    /// construction, then mirrored verbatim so ship-seqs stay aligned
    /// across the pair. Returns whether the record advanced the log.
    pub fn apply_replicated(&self, rec: ReplRecord, now: VirtualTime) -> Result<bool, FsError> {
        let _ingest = self.repl_ingest.lock().unwrap();
        {
            let g = self.repl.lock().unwrap();
            let watermark = g.ship_seq();
            if rec.ship_seq <= watermark {
                return Ok(false);
            }
            if rec.ship_seq != watermark + 1 {
                return Err(FsError::Protocol(format!(
                    "replication gap: got ship_seq {} at watermark {watermark}",
                    rec.ship_seq
                )));
            }
        }
        match &rec.payload {
            ReplPayload::Op { client_id, seq, op, .. } => {
                // a spilled WriteRef materializes back into the exact
                // WriteFull it came from (same digests/base_version, so
                // the conflict comparison re-runs identically); then the
                // record applied on the primary; replaying the same op
                // against the same mirrored state is deterministic, so a
                // non-Applied answer here means divergence — which the
                // convergence invariants (I3/I4) surface loudly.
                let op = self.materialize_op(op.clone())?;
                let _ = self.apply(*client_id, *seq, op, now, false);
            }
            ReplPayload::Failed { client_id, seq, path } => {
                let key = vpath::normalize(path);
                let mut g = self.lock_shard(self.shard_of(&key));
                let set = g.failed.entry(*client_id).or_default();
                set.insert(*seq);
                while set.len() > Self::MAX_FAILED_SEQS {
                    set.pop_first();
                }
            }
            ReplPayload::Local { op } => match self.materialize_op(op.clone())? {
                MetaOp::WriteFull { path, data, .. } => {
                    let key = vpath::normalize(&path);
                    let mut g = self.lock_shard(self.shard_of(&key));
                    self.fs.write().unwrap().write(&key, &data, now)?;
                    g.purge_digests(&key);
                }
                MetaOp::Unlink { path } => {
                    let key = vpath::normalize(&path);
                    let mut g = self.lock_shard(self.shard_of(&key));
                    let _ = self.fs.write().unwrap().unlink(&key, now);
                    g.purge_digests(&key);
                }
                // local edits are only ever writes/unlinks; anything
                // else in a Local record is mirrored without effect
                _ => {}
            },
        }
        // a mirrored WriteRef record pins its chunks exactly like the
        // primary's log copy does (released when THIS log truncates);
        // fs lock before the log lock, matching the apply path's order
        {
            let op = match &rec.payload {
                ReplPayload::Op { op, .. } | ReplPayload::Local { op } => Some(op),
                ReplPayload::Failed { .. } => None,
            };
            if let Some(MetaOp::WriteRef { chunks, .. }) = op {
                let mut fs = self.fs.write().unwrap();
                for d in chunks {
                    fs.incref_chunk(d);
                }
            }
        }
        let mut g = self.repl.lock().unwrap();
        debug_assert_eq!(g.ship_seq() + 1, rec.ship_seq);
        if let Some(w) = g.shard_watermarks.get_mut(rec.shard as usize) {
            *w = rec.ship_seq;
        }
        g.records.push(rec);
        Ok(true)
    }

    /// Direct (trusted) access to the home space — for tests and the
    /// workload generators that PRE-POPULATE the home space before any
    /// client has cached anything. Returns a write guard over the inode
    /// substrate and bypasses the digest-cache purge + callback fanout
    /// entirely: once clients are attached, home-side edits must go
    /// through [`Self::local_write`]/[`Self::local_unlink`] instead
    /// (an unlink+recreate through this guard restarts the inode's
    /// version at 1 and can collide with a cached digest entry).
    pub fn home_mut(&self) -> RwLockWriteGuard<'_, FileStore> {
        self.fs.write().unwrap()
    }

    pub fn home(&self) -> RwLockReadGuard<'_, FileStore> {
        self.fs.read().unwrap()
    }

    pub fn is_up(&self) -> bool {
        self.up.load(Ordering::SeqCst)
    }

    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    /// Number of namespace shards (`[server] shards` in `xufs.toml`).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard a canonical path routes to. Public so tests and the
    /// scale harness can construct provably co-/cross-shard path sets.
    pub fn shard_of(&self, path: &str) -> usize {
        let key = vpath::normalize(path);
        (fnv1a(key.as_bytes()) % self.shards.len() as u64) as usize
    }

    /// Turn modeled disk service waits on/off (wall-clock deployments
    /// only — see `bench/scale.rs`). Metadata ops sleep `disk.op_secs()`
    /// under their shard lock (the serialization a real home disk
    /// imposes). FETCH payloads sleep their streaming time outside any
    /// shard lock (the parallel data plane); WRITE payloads sleep it
    /// under the path's shard lock, deliberately — a home disk
    /// serializes same-subtree writes, and the old global lock
    /// serialized ALL of them.
    pub fn set_modeled_disk_waits(&self, enabled: bool) {
        self.modeled_waits.store(enabled, Ordering::Relaxed);
    }

    fn op_wait(&self) {
        if self.modeled_waits.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_secs_f64(self.disk.op_secs()));
        }
    }

    fn io_wait(&self, bytes: u64) {
        if self.modeled_waits.load(Ordering::Relaxed) && bytes > 0 {
            std::thread::sleep(Duration::from_secs_f64(bytes as f64 / self.disk.bps));
        }
    }

    /// Lock one shard, counting acquisitions that had to block behind
    /// another request (`server.shard_contention`).
    fn lock_shard(&self, idx: usize) -> MutexGuard<'_, Shard> {
        if let Ok(g) = self.shards[idx].try_lock() {
            return g;
        }
        self.metrics.incr(names::SHARD_CONTENTION);
        self.shards[idx].lock().unwrap()
    }

    /// Lock every shard in ascending index order (registry broadcasts,
    /// crash). The same ascending rule as cross-shard renames keeps the
    /// core deadlock-free.
    fn lock_all(&self) -> Vec<MutexGuard<'_, Shard>> {
        (0..self.shards.len()).map(|i| self.lock_shard(i)).collect()
    }

    /// Route a bare lock token back to the shard that minted it.
    fn shard_of_token(&self, token: u64) -> usize {
        (token.wrapping_sub(1) % self.shards.len() as u64) as usize
    }

    /// Crash the server process: callback registrations and the in-memory
    /// lock table die with it; the home space (on disk) survives — and so
    /// does the per-client idempotence watermark (`applied`/`failed`),
    /// which the server journals to the home disk alongside the data it
    /// guards. A crashed-and-restarted server must answer replayed ops
    /// as duplicates, not re-apply them: re-application would double-bump
    /// versions and mistake a client's own earlier write for a
    /// conflicting third-party edit (DESIGN.md §2.5).
    pub fn crash(&self) {
        self.up.store(false, Ordering::SeqCst);
        let n = self.shards.len();
        let mut guards = self.lock_all();
        for (i, g) in guards.iter_mut().enumerate() {
            for reg in &g.callbacks {
                reg.channel.disconnect();
            }
            g.callbacks.clear();
            g.locks = LockTable::with_tokens(self.lease_s, i as u64 + 1, n as u64);
        }
    }

    /// Restart (the paper uses a crontab job). Clients must re-register
    /// callbacks and re-acquire locks.
    pub fn restart(&self) {
        self.up.store(true, Ordering::SeqCst);
    }

    /// A change made *at the home space directly* (the user editing a file
    /// on their workstation). Bumps the store and fans out invalidations
    /// to every registered client.
    pub fn local_write(&self, path: &str, data: &[u8], now: VirtualTime) -> Result<(), FsError> {
        let key = vpath::normalize(path);
        let idx = self.shard_of(&key);
        let mut g = self.lock_shard(idx);
        self.fs.write().unwrap().write(&key, data, now)?;
        g.purge_digests(&key);
        let version = self.fs.read().unwrap().stat(&key).map(|a| a.version).unwrap_or(0);
        self.notify_change_in(&g, &key, version, None);
        // home-side edits replicate as Local records: no client seq, no
        // watermark — the secondary just mirrors the store change
        if self.replication_enabled() && self.role() == Role::Primary {
            self.log_record(
                idx,
                ReplPayload::Local {
                    op: MetaOp::WriteFull {
                        path: key.clone(),
                        data: data.to_vec(),
                        digests: Vec::new(),
                        base_version: 0,
                    },
                },
            );
        }
        self.maybe_gc();
        Ok(())
    }

    pub fn local_unlink(&self, path: &str, now: VirtualTime) -> Result<(), FsError> {
        let key = vpath::normalize(path);
        let idx = self.shard_of(&key);
        let mut g = self.lock_shard(idx);
        self.fs.write().unwrap().unlink(&key, now)?;
        g.purge_digests(&key);
        self.notify_removed_in(&g, &key, None);
        if self.replication_enabled() && self.role() == Role::Primary {
            self.log_record(idx, ReplPayload::Local { op: MetaOp::Unlink { path: key.clone() } });
        }
        self.maybe_gc();
        Ok(())
    }

    fn notify_change_in(&self, shard: &Shard, path: &str, new_version: u64, originator: Option<u64>) {
        let p = vpath::normalize(path);
        for reg in &shard.callbacks {
            if Some(reg.client_id) == originator {
                continue;
            }
            if vpath::is_under(&p, &reg.root)
                && reg.channel.push(NotifyEvent::Invalidate { path: p.clone(), new_version })
            {
                self.metrics.incr(names::CALLBACKS_SENT);
            }
        }
    }

    fn notify_removed_in(&self, shard: &Shard, path: &str, originator: Option<u64>) {
        let p = vpath::normalize(path);
        for reg in &shard.callbacks {
            if Some(reg.client_id) == originator {
                continue;
            }
            if vpath::is_under(&p, &reg.root)
                && reg.channel.push(NotifyEvent::Removed { path: p.clone() })
            {
                self.metrics.incr(names::CALLBACKS_SENT);
            }
        }
    }

    /// Expire orphaned lock leases across every shard (invoked by the
    /// coordinator's housekeeping tick; conflicting acquires expire
    /// their own shard inline).
    pub fn expire_leases(&self, now: VirtualTime) -> usize {
        let mut total = 0;
        for i in 0..self.shards.len() {
            total += self.lock_shard(i).locks.expire(now);
        }
        if total > 0 {
            self.metrics.add(names::LEASE_EXPIRED, total as u64);
        }
        total
    }

    /// Digest-cache lookup/compute with the shard guard HELD — only the
    /// rare conflict-detection path inside `apply` uses this; the bulk
    /// fetch paths use [`Self::cached_digests_at`]/[`Self::install_digests`]
    /// so the digest pass itself runs outside any shard lock.
    fn digests_in(&self, shard: &mut Shard, path: &str, version: u64) -> Vec<i32> {
        let key = vpath::normalize(path);
        if let Some((v, d)) = shard.digest_cache.get(&key) {
            if *v == version {
                return d.clone();
            }
        }
        let data = self.fs.read().unwrap().read(&key).map(|d| d.to_vec()).unwrap_or_default();
        let digests = self.engine.digests(&data, self.block_bytes);
        shard.digest_cache.insert(key, (version, digests.clone()));
        digests
    }

    /// Version-gated digest-cache probe that ALSO requires the purge
    /// epoch unchanged since `epoch`: every fetch-path caller pairs the
    /// returned digests with a stat/data snapshot taken at that epoch,
    /// and a hit installed after an invalidation (a same-version rename
    /// by another client) must not be paired with pre-invalidation
    /// state — an epoch mismatch forces a recompute from the caller's
    /// own snapshot (self-consistent by construction). Must NOT be
    /// called while this shard's guard is already held.
    fn cached_digests_at(
        &self,
        idx: usize,
        key: &str,
        version: u64,
        epoch: u64,
    ) -> Option<Vec<i32>> {
        let g = self.lock_shard(idx);
        if g.purge_epoch != epoch {
            return None;
        }
        match g.digest_cache.get(key) {
            Some((v, d)) if *v == version => Some(d.clone()),
            _ => None,
        }
    }

    /// The shard's current purge epoch (brief shard lock). Read BEFORE
    /// snapshotting data for an unlocked digest pass; pass the value to
    /// [`Self::install_digests`].
    fn digest_epoch(&self, idx: usize) -> u64 {
        self.lock_shard(idx).purge_epoch
    }

    /// Install freshly computed digests (brief shard lock) — unless a
    /// purge happened since `epoch` was read, in which case the pass may
    /// have snapshotted content that a rename/unlink/local edit replaced
    /// and installing would poison the cache (the next fetch just
    /// recomputes). Also refuses to clobber an entry a newer-version
    /// pass already installed (versions are monotone within an inode's
    /// lifetime; inode swaps always bump the epoch). Must NOT be called
    /// while this shard's guard is held.
    fn install_digests(&self, idx: usize, key: &str, version: u64, digests: Vec<i32>, epoch: u64) {
        let mut g = self.lock_shard(idx);
        if g.purge_epoch != epoch {
            return;
        }
        if let Some((v, _)) = g.digest_cache.get(key) {
            if *v > version {
                return;
            }
        }
        g.digest_cache.insert(key.to_string(), (version, digests));
    }

    /// `(version, size, digests)` for a path — the digest pass (a whole-
    /// file read + checksum) runs outside any shard lock, guarded by the
    /// purge epoch so it never installs over a concurrent invalidation.
    fn file_meta(&self, idx: usize, key: &str) -> Result<(u64, u64, Vec<i32>), FsError> {
        let epoch = self.digest_epoch(idx);
        let a = self.fs.read().unwrap().stat(key)?;
        if let Some(d) = self.cached_digests_at(idx, key, a.version, epoch) {
            return Ok((a.version, a.size, d));
        }
        let (a, data) = {
            let fs = self.fs.read().unwrap();
            let a = fs.stat(key)?;
            // an unreadable file digests as empty (directories etc.) —
            // EXCEPT integrity refusals, which must propagate: digesting
            // rot as "empty at version v" would be silent corruption
            let data = match fs.read(key) {
                Ok(d) => d,
                Err(e @ FsError::Corrupted(_)) => return Err(e),
                Err(_) => Vec::new(),
            };
            (a, data)
        };
        let digests = self.engine.digests(&data, self.block_bytes);
        self.install_digests(idx, key, a.version, digests.clone(), epoch);
        Ok((a.version, a.size, digests))
    }

    /// Handle one authenticated request from `client_id`. Takes `&self`:
    /// concurrent callers serialize only on the shard(s) their paths
    /// route to (plus brief store read/write sections).
    pub fn handle(&self, client_id: u64, req: Request, now: VirtualTime) -> Response {
        if !self.is_up() {
            return Response::Err { code: 111, msg: "connection refused (server down)".into() };
        }
        // background integrity scrub rides the op cadence (DESIGN.md
        // §2.10), exactly like the deferred GC rides the apply cadence
        self.maybe_scrub();
        // replica-pair role gate (DESIGN.md §2.7): a standby serves only
        // the replication plane until promoted; a fenced ex-primary
        // serves nothing mutable ever again. Code 112 is the links'
        // "wrong endpoint — fail over" signal.
        match self.role() {
            Role::Primary => {
                if matches!(req, Request::Replicate { .. } | Request::ChunkPush { .. }) {
                    return Response::Err {
                        code: 112,
                        msg: "replication-plane request refused: this node is the primary".into(),
                    };
                }
            }
            Role::Secondary => {
                // The replication plane always; read-only traffic too
                // once read fan-out is on (DESIGN.md §2.11).
                // RegisterCallback stays refused either way: a client
                // that could complete its mount handshake here would
                // bind its callback promise to a node that never
                // originates invalidations — the 112 makes its connect
                // attempt fail so endpoint rotation keeps looking for
                // the serving node. Mutations are likewise refused: the
                // secondary's store only moves by ingesting the log.
                let allowed = matches!(
                    req,
                    Request::Ping
                        | Request::Replicate { .. }
                        | Request::ChunkPush { .. }
                        | Request::ChunkFetch { .. }
                        | Request::WatermarkQuery { .. }
                        | Request::Promote
                );
                let read = self.read_serving()
                    && matches!(
                        req,
                        Request::Stat { .. }
                            | Request::ReadDir { .. }
                            | Request::Fetch { .. }
                            | Request::FetchMeta { .. }
                            | Request::FetchRange { .. }
                    );
                if read {
                    // bounded-staleness gate: a replica that has drifted
                    // more than `staleness_ops` applied ops behind the
                    // primary's last-announced log head serves NOTHING
                    // until shipping catches it back up — the blanket
                    // bound the per-path version floors ride on top of.
                    let head = self.known_repl_head();
                    let lag = head.saturating_sub(self.repl_ship_seq());
                    let bound = self.staleness_limit.load(Ordering::SeqCst);
                    if lag > bound {
                        return self.too_stale(format!(
                            "replica is {lag} ops behind the primary's log head \
                             (staleness bound {bound}): fall back to the primary"
                        ));
                    }
                    self.metrics.incr(names::REPLICA_READ_HITS);
                }
                if !allowed && !read {
                    return Response::Err {
                        code: 112,
                        msg: "not primary (standby replica): fail over".into(),
                    };
                }
            }
            Role::Retired => {
                if !matches!(req, Request::Ping | Request::WatermarkQuery { .. }) {
                    return Response::Err {
                        code: 112,
                        msg: "retired primary (fenced after promotion): fail over".into(),
                    };
                }
            }
        }
        match req {
            Request::AuthHello { .. } | Request::AuthProof { .. } => Response::Err {
                code: 1,
                msg: "auth is handled by the transport handshake".into(),
            },
            Request::Ping => Response::Pong,
            Request::Stat { path } => {
                let _g = self.lock_shard(self.shard_of(&path));
                self.op_wait();
                match self.fs.read().unwrap().stat(&path) {
                    Ok(a) => Response::Attr { attr: WireAttr::from_attr(&a) },
                    Err(e) => err_resp(&e),
                }
            }
            Request::ReadDir { path } => {
                let _g = self.lock_shard(self.shard_of(&path));
                self.op_wait();
                match self.fs.read().unwrap().readdir(&path) {
                    Ok(entries) => Response::Dir {
                        entries: entries
                            .into_iter()
                            .map(|(name, a)| DirEntry { name, attr: WireAttr::from_attr(&a) })
                            .collect(),
                    },
                    Err(e) => err_resp(&e),
                }
            }
            Request::Fetch { path, min_version } => {
                let key = vpath::normalize(&path);
                let idx = self.shard_of(&key);
                // per-path staleness floor (DESIGN.md §2.11): on a
                // serving secondary, a copy older than the highest
                // version this client has observed is a monotonicity
                // violation waiting to happen — refuse it. The primary
                // ignores the floor: it IS the freshest copy.
                let enforce_floor = min_version > 0 && self.role() == Role::Secondary;
                // admission: the namespace op serializes on its shard...
                {
                    let _g = self.lock_shard(idx);
                    self.op_wait();
                }
                // ...but the block read + digest pass run OUTSIDE any
                // shard lock, so fetches from different clients overlap
                // (§2.6). One read section => a consistent snapshot; the
                // epoch (read first) keeps the later install from racing
                // a concurrent invalidation of this path.
                let epoch = self.digest_epoch(idx);
                let snap = {
                    let fs = self.fs.read().unwrap();
                    match fs.stat(&key) {
                        // an unreadable file serves as empty (directories
                        // etc.) — EXCEPT integrity refusals, which must
                        // propagate rather than serve rot as "empty"
                        Ok(a) => match fs.read(&key) {
                            Ok(d) => Ok((a.version, d)),
                            Err(e @ FsError::Corrupted(_)) => Err(e),
                            Err(_) => Ok((a.version, Vec::new())),
                        },
                        Err(e) => Err(e),
                    }
                };
                match snap {
                    Ok((version, _)) if enforce_floor && version < min_version => self
                        .too_stale(format!(
                            "{key} is at v{version} on this replica, below the client's \
                             observed floor v{min_version}"
                        )),
                    Err(FsError::NotFound(_)) if enforce_floor => self.too_stale(format!(
                        "{key} not yet replicated here (client observed v{min_version})"
                    )),
                    Ok((version, data)) => {
                        self.io_wait(data.len() as u64);
                        let digests = match self.cached_digests_at(idx, &key, version, epoch) {
                            Some(d) => d,
                            None => {
                                let d = self.engine.digests(&data, self.block_bytes);
                                self.install_digests(idx, &key, version, d.clone(), epoch);
                                d
                            }
                        };
                        Response::File { image: FileImage { path: key, version, data, digests } }
                    }
                    Err(e) => err_resp(&e),
                }
            }
            Request::FetchMeta { path, min_version } => {
                let key = vpath::normalize(&path);
                let idx = self.shard_of(&key);
                let enforce_floor = min_version > 0 && self.role() == Role::Secondary;
                {
                    let _g = self.lock_shard(idx);
                    self.op_wait();
                }
                match self.file_meta(idx, &key) {
                    Ok((version, _, _)) if enforce_floor && version < min_version => self
                        .too_stale(format!(
                            "{key} is at v{version} on this replica, below the client's \
                             observed floor v{min_version}"
                        )),
                    Ok((version, size, digests)) => Response::FileMeta { version, size, digests },
                    Err(FsError::NotFound(_)) if enforce_floor => self.too_stale(format!(
                        "{key} not yet replicated here (client observed v{min_version})"
                    )),
                    Err(e) => err_resp(&e),
                }
            }
            Request::FetchRange { path, offset, len, expect_version } => {
                let key = vpath::normalize(&path);
                let idx = self.shard_of(&key);
                // admission
                {
                    let _g = self.lock_shard(idx);
                    self.op_wait();
                }
                // `expect_version` is an exact pin, so it doubles as the
                // staleness floor on a serving secondary (DESIGN.md
                // §2.11): a replica copy BELOW the pin is the replica
                // lagging (119: retry toward the primary), a copy ABOVE
                // it means the file really changed under the fetch
                // (116: refresh and refetch) — the same split a missing
                // path takes (not yet replicated vs truly gone).
                let on_secondary = self.role() == Role::Secondary;
                let stale = |v: u64| {
                    if on_secondary && v < expect_version {
                        self.too_stale(format!(
                            "{path} is at v{v} on this replica, behind the pinned \
                             fetch version v{expect_version}"
                        ))
                    } else {
                        err_resp(&FsError::Stale(format!(
                            "{path} changed during striped fetch (v{v} != v{expect_version})"
                        )))
                    }
                };
                let missing = |e: &FsError| {
                    if on_secondary && matches!(e, FsError::NotFound(_)) {
                        self.too_stale(format!(
                            "{path} not yet replicated here (pinned fetch v{expect_version})"
                        ))
                    } else {
                        err_resp(e)
                    }
                };
                // Digest resolution and the block copy are separate
                // lock-free(ish) sections; the purge epoch brackets the
                // whole attempt so an interleaved invalidation (e.g. a
                // rename that preserves the moved inode's version — the
                // case versions alone cannot gate) can never pair one
                // content's digests with another's bytes. Purges are
                // rare: the loop converges on its first pass in
                // practice, and a pathological churn storm surfaces as
                // Stale, which the client answers with a refresh.
                for _ in 0..4 {
                    let epoch = self.digest_epoch(idx);
                    match self.fs.read().unwrap().stat(&key) {
                        Ok(a) if a.version != expect_version => return stale(a.version),
                        Ok(_) => {}
                        Err(e) => return missing(&e),
                    }
                    // digests from the cache, or a whole-file digest
                    // pass — either way outside any shard lock
                    let digests =
                        match self.cached_digests_at(idx, &key, expect_version, epoch) {
                            Some(d) => d,
                            None => match self.file_meta(idx, &key) {
                                Ok((v, _, d)) if v == expect_version => d,
                                Ok((v, _, _)) => return stale(v),
                                Err(e) => return missing(&e),
                            },
                        };
                    // copy the covering blocks in ONE store read
                    // section, re-gating the version so a racing write
                    // cannot tear the reply; serve whole blocks with
                    // their digests so the client verifies and installs
                    // them independently
                    let extents = {
                        let fs = self.fs.read().unwrap();
                        let a = match fs.stat(&key) {
                            Ok(a) => a,
                            Err(e) => return missing(&e),
                        };
                        if a.version != expect_version {
                            return stale(a.version);
                        }
                        let bb = self.block_bytes.max(1) as u64;
                        let total = a.size.div_ceil(bb);
                        let first = (offset / bb).min(total);
                        let last = offset.saturating_add(len).min(a.size).div_ceil(bb);
                        let mut extents =
                            Vec::with_capacity(last.saturating_sub(first) as usize);
                        let mut failed = None;
                        for b in first..last {
                            let boff = b * bb;
                            let blen = bb.min(a.size - boff) as usize;
                            match fs.read_at(&key, boff, blen) {
                                Ok(data) => extents.push(BlockExtent {
                                    index: b as u32,
                                    data: data.to_vec(),
                                    digest: digests.get(b as usize).copied().unwrap_or(0),
                                }),
                                Err(e) => {
                                    failed = Some(e);
                                    break;
                                }
                            }
                        }
                        if let Some(e) = failed {
                            return err_resp(&e);
                        }
                        extents
                    };
                    if self.digest_epoch(idx) != epoch {
                        // an invalidation interleaved between the digest
                        // resolution and the block copy — retry against
                        // the settled state
                        continue;
                    }
                    self.io_wait(extents.iter().map(|x| x.data.len() as u64).sum::<u64>());
                    return Response::FileBlocks { version: expect_version, extents };
                }
                err_resp(&FsError::Stale(format!(
                    "{path} kept changing during striped fetch (aborted by concurrent \
                     invalidations; refetch at the current version)"
                )))
            }
            Request::RegisterCallback { root, client_id: cid } => {
                // the registry is replicated to every shard (so fanout
                // never leaves the mutating op's shard): broadcast under
                // the ordered all-shard lock path
                if self.shards.len() > 1 {
                    self.metrics.incr(names::CROSS_SHARD_OPS);
                }
                // leaf mutex, taken and released before any shard lock
                let channel =
                    self.channel_map.lock().unwrap().get(&cid).cloned().unwrap_or_default();
                let mut guards = self.lock_all();
                self.op_wait();
                let root_n = vpath::normalize(&root);
                for g in guards.iter_mut() {
                    // replace any prior registration for this client+root
                    g.callbacks.retain(|r| !(r.client_id == cid && r.root == root_n));
                    g.callbacks.push(CallbackReg {
                        client_id: cid,
                        root: root_n.clone(),
                        channel: channel.clone(),
                    });
                }
                Response::CallbackRegistered
            }
            Request::Apply { seq, op } => self.apply(client_id, seq, op, now, true),
            Request::Compound { ops } => {
                // one WAN round trip, N ops: each op gets the exact
                // Response its single-op request would have produced, so
                // the client sees partial failure per op and replays only
                // what did not land (idempotent via per-client seqs).
                // Each op takes its own shard lock(s) in turn — a frame
                // spanning shards never holds two shard locks at once
                // except through the ordered rename path.
                // (Round-trip accounting lives client-side in the links —
                // the sim deployment shares one metrics sink.)
                let replies = ops
                    .into_iter()
                    .map(|op| match op {
                        CompoundOp::Apply { seq, op } => self.apply(client_id, seq, op, now, true),
                        CompoundOp::Stat { path } => {
                            let _g = self.lock_shard(self.shard_of(&path));
                            self.op_wait();
                            match self.fs.read().unwrap().stat(&path) {
                                Ok(a) => Response::Attr { attr: WireAttr::from_attr(&a) },
                                Err(e) => err_resp(&e),
                            }
                        }
                    })
                    .collect();
                Response::CompoundReply { replies }
            }
            Request::LockAcquire { path, kind, owner } => {
                let key = vpath::normalize(&path);
                let mut g = self.lock_shard(self.shard_of(&key));
                self.op_wait();
                let expired = g.locks.expire(now);
                if expired > 0 {
                    self.metrics.add(names::LEASE_EXPIRED, expired as u64);
                }
                match g.locks.acquire(&key, kind, owner, now) {
                    Acquire::Granted { token, lease } => Response::LockGranted {
                        token,
                        lease_ns: lease.saturating_sub(now).0,
                    },
                    Acquire::Denied { holder } => Response::LockDenied { holder },
                }
            }
            Request::LockRenew { token, owner } => {
                let mut g = self.lock_shard(self.shard_of_token(token));
                self.op_wait();
                match g.locks.renew(token, owner, now) {
                    Some(expires) => {
                        self.metrics.incr(names::LEASE_RENEWALS);
                        Response::LockGranted { token, lease_ns: expires.saturating_sub(now).0 }
                    }
                    None => Response::Err { code: 77, msg: "lease lost".into() },
                }
            }
            Request::LockRelease { token, owner } => {
                let mut g = self.lock_shard(self.shard_of_token(token));
                self.op_wait();
                if g.locks.release(token, owner) {
                    Response::Released
                } else {
                    Response::Err { code: 77, msg: "no such lock".into() }
                }
            }
            Request::Replicate { from, frames, head } => {
                // reachable only on a Secondary (role gate above)
                //
                // record the primary's announced log head FIRST — even a
                // batch that then stalls on missing chunks must tighten
                // the staleness gate (the announcement is what tells a
                // serving replica it has fallen behind)
                self.known_head.fetch_max(head, Ordering::SeqCst);
                let records = match crate::replica::decode_frames(&frames) {
                    Ok(r) => r,
                    Err(e) => {
                        return Response::Err {
                            code: 74,
                            msg: format!("replication batch refused: {e}"),
                        }
                    }
                };
                let _ = from; // the frames carry authoritative ship-seqs
                // ref-based shipping (DESIGN.md §2.8): before ANYTHING
                // applies, scan the batch's un-ingested WriteRef records
                // for chunks this store lacks and ask the shipper to
                // push those payloads first — the whole batch then lands
                // atomically on the retry.
                {
                    let watermark = self.repl_ship_seq();
                    let fs = self.fs.read().unwrap();
                    let mut seen: HashSet<Digest> = HashSet::new();
                    let mut need: Vec<Digest> = Vec::new();
                    for rec in &records {
                        if rec.ship_seq <= watermark {
                            continue; // idempotent re-ship: already applied
                        }
                        let op = match &rec.payload {
                            ReplPayload::Op { op, .. } | ReplPayload::Local { op } => op,
                            ReplPayload::Failed { .. } => continue,
                        };
                        if let MetaOp::WriteRef { chunks, .. } = op {
                            if !fs.is_chunked() {
                                return Response::Err {
                                    code: 74,
                                    msg: "replication batch refused: ref-shipped records \
                                          into a dense (chunkstore-disabled) store"
                                        .into(),
                                };
                            }
                            for d in chunks {
                                if !fs.has_chunk(d) && seen.insert(*d) {
                                    need.push(*d);
                                }
                            }
                        }
                    }
                    if !need.is_empty() {
                        return Response::ReplicaNeed { digests: need };
                    }
                }
                for rec in records {
                    match self.apply_replicated(rec, now) {
                        Ok(_) => {}
                        Err(e) => return err_resp(&e),
                    }
                }
                // the batch landed: release the transfer pins ChunkPush
                // staged for it (file/snapshot/log residency holds its
                // own references by now; anything unused goes dead and
                // the deferred GC sweeps it)
                {
                    let mut fs = self.fs.write().unwrap();
                    let mut staged = self.staged_chunks.lock().unwrap();
                    for d in staged.drain(..) {
                        fs.decref_chunk(&d);
                    }
                }
                Response::ReplicaAck { watermark: self.repl_ship_seq() }
            }
            Request::ChunkPush { chunks } => {
                // reachable only on a Secondary (role gate above): stage
                // chunk payloads ahead of the Replicate batch that
                // references them. Each staged chunk holds one transfer
                // pin so a GC sweep between push and batch-apply cannot
                // collect it; re-pushes after a lost ack just stack
                // another pin (released with the rest).
                let mut stored = 0u64;
                {
                    let mut fs = self.fs.write().unwrap();
                    if !fs.is_chunked() {
                        return err_resp(&FsError::Invalid(
                            "chunk push into a dense (chunkstore-disabled) store".into(),
                        ));
                    }
                    let mut staged = self.staged_chunks.lock().unwrap();
                    for bytes in &chunks {
                        if let Ok(d) = fs.insert_chunk(bytes) {
                            staged.push(d);
                            stored += 1;
                        }
                    }
                }
                Response::ChunkAck { stored }
            }
            Request::SnapshotCreate => {
                // CoW snapshot (DESIGN.md §2.8): pin every live chunk
                // and clone the inode table — O(metadata), zero data
                // copied. The ordered all-shard lock makes the image a
                // consistent cut across concurrent appliers.
                let _guards = self.lock_all();
                self.op_wait();
                match self.fs.write().unwrap().snapshot(now) {
                    Ok(id) => {
                        self.metrics.incr(names::CHUNK_SNAPSHOTS);
                        Response::SnapshotCreated { id }
                    }
                    Err(e) => err_resp(&e),
                }
            }
            Request::ChunkFetch { digests } => {
                // repair plane (DESIGN.md §2.10): serve digest-verified
                // chunk bytes so a peer can heal its quarantined copy.
                // Rotted or missing chunks are silently omitted — this
                // node never ships bytes it cannot vouch for, and the
                // requester matches fills by recomputing digests anyway.
                Response::ChunkFill { chunks: self.read_chunks(&digests) }
            }
            Request::WatermarkQuery { shard } => {
                Response::Watermark { shard, watermark: self.repl_watermark(shard as usize) }
            }
            Request::Promote => {
                // the explicit takeover step (idempotent on a primary;
                // a Retired node never reaches here — role gate)
                self.set_role(Role::Primary);
                Response::Promoted { watermark: self.repl_ship_seq() }
            }
        }
    }

    /// Attach (or create) the callback channel for a client. The transport
    /// owns the other end. Existing registrations are re-pointed in every
    /// shard's replicated registry (ordered broadcast); the channel map
    /// itself keeps one copy behind its own leaf mutex so a later
    /// `RegisterCallback` can find it.
    pub fn attach_channel(&self, client_id: u64, channel: NotifyChannel) {
        if self.shards.len() > 1 {
            self.metrics.incr(names::CROSS_SHARD_OPS);
        }
        self.channel_map.lock().unwrap().insert(client_id, channel.clone());
        let mut guards = self.lock_all();
        for g in guards.iter_mut() {
            for reg in g.callbacks.iter_mut() {
                if reg.client_id == client_id {
                    reg.channel = channel.clone();
                }
            }
        }
    }

    /// Retained failed-seq records per client (tiny; evicting the oldest
    /// only risks falsely acking a replay of a very stale failed op).
    const MAX_FAILED_SEQS: usize = 1024;

    /// Route an op to its shard(s) and apply it. Cross-shard renames take
    /// both locks in ascending index order; DIRECTORY renames take every
    /// shard lock (still ascending) so the descendant digest sweep is
    /// atomic with the move. One ordering rule, so no deadlock.
    ///
    /// `log = true` records the application outcome in the replication
    /// log (when enabled); the secondary's ingest path passes `false`
    /// and mirrors the shipped record verbatim instead, so ship-seqs
    /// stay aligned across the pair.
    fn apply(&self, client_id: u64, seq: u64, op: MetaOp, now: VirtualTime, log: bool) -> Response {
        let primary = self.shard_of(op.path());
        let rename_pair = match &op {
            MetaOp::Rename { from, to } => {
                Some((vpath::normalize(from), vpath::normalize(to)))
            }
            _ => None,
        };
        let secondary = rename_pair.as_ref().and_then(|(_, to)| {
            let t = self.shard_of(to);
            if t == primary {
                None
            } else {
                Some(t)
            }
        });
        // A DIRECTORY rename moves a whole subtree: the descendants'
        // cached digest entries live in arbitrary shards (path-hash
        // routing) and their inodes keep their versions, so a recreate
        // under the old path could collide with a stale entry. Take
        // every shard lock and sweep both subtree prefixes atomically
        // with the move. (The kind probe is lock-free; the pathological
        // race — another client swapping the path's kind between probe
        // and locks — is covered by the post-apply fallback below.)
        let subtree_move = rename_pair.is_some()
            && self
                .fs
                .read()
                .unwrap()
                .stat(op.path())
                .map(|a| a.kind == NodeKind::Dir)
                .unwrap_or(false);
        if subtree_move {
            if self.shards.len() > 1 {
                self.metrics.incr(names::CROSS_SHARD_OPS);
            }
            let (from_p, to_p) = rename_pair.expect("subtree_move implies a rename");
            let mut guards = self.lock_all();
            self.op_wait();
            let was_dup = guards[primary].is_duplicate(client_id, seq);
            let resp = match secondary {
                None => self.apply_in(&mut guards[primary], None, primary, client_id, seq, op, now, log),
                Some(sec) => {
                    let (lo_i, hi_i) = (primary.min(sec), primary.max(sec));
                    let (left, right) = guards.split_at_mut(hi_i);
                    let lo: &mut Shard = &mut left[lo_i];
                    let hi: &mut Shard = &mut right[0];
                    if primary < sec {
                        self.apply_in(lo, Some(hi), primary, client_id, seq, op, now, log)
                    } else {
                        self.apply_in(hi, Some(lo), primary, client_id, seq, op, now, log)
                    }
                }
            };
            // sweep only when the op genuinely applied: a replayed
            // duplicate changed nothing, and purging on every replay
            // would needlessly abort in-flight digest passes
            if !was_dup && matches!(resp, Response::Applied { .. }) {
                for g in guards.iter_mut() {
                    g.digest_cache.retain(|k, _| {
                        !vpath::is_under(k, &from_p) && !vpath::is_under(k, &to_p)
                    });
                    g.purge_epoch += 1;
                }
            }
            return resp;
        }
        let (resp, was_dup) = match secondary {
            None => {
                let mut g = self.lock_shard(primary);
                self.op_wait();
                let dup = g.is_duplicate(client_id, seq);
                (self.apply_in(&mut g, None, primary, client_id, seq, op, now, log), dup)
            }
            Some(sec) => {
                self.metrics.incr(names::CROSS_SHARD_OPS);
                let (mut a, mut b) = if primary < sec {
                    let a = self.lock_shard(primary);
                    let b = self.lock_shard(sec);
                    (a, b)
                } else {
                    let b = self.lock_shard(sec);
                    let a = self.lock_shard(primary);
                    (a, b)
                };
                self.op_wait();
                let dup = a.is_duplicate(client_id, seq);
                (self.apply_in(&mut a, Some(&mut b), primary, client_id, seq, op, now, log), dup)
            }
        };
        // fallback for the probe race above: the moved node turned out
        // to be a directory after all — sweep after release (a tiny
        // window, reachable only if another client swapped the path's
        // kind between the probe and the locks). Replayed duplicates
        // changed nothing and never sweep.
        if was_dup {
            return resp;
        }
        if let (Some((from_p, to_p)), Response::Applied { .. }) = (&rename_pair, &resp) {
            let moved_dir = self
                .fs
                .read()
                .unwrap()
                .stat(to_p)
                .map(|a| a.kind == NodeKind::Dir)
                .unwrap_or(false);
            if moved_dir {
                for i in 0..self.shards.len() {
                    let mut g = self.lock_shard(i);
                    g.digest_cache.retain(|k, _| {
                        !vpath::is_under(k, from_p) && !vpath::is_under(k, to_p)
                    });
                    g.purge_epoch += 1;
                }
            }
        }
        resp
    }

    /// Apply one meta-op with its shard guard(s) held. `shard` is the
    /// primary (the op's path); `shard_idx` its index (replication-log
    /// routing); `to_shard` is the rename target's shard when that
    /// differs. `log` records the outcome in the applied-op log
    /// (suppressed on the secondary's ingest path, which mirrors the
    /// shipped record instead).
    #[allow(clippy::too_many_arguments)]
    fn apply_in(
        &self,
        shard: &mut Shard,
        to_shard: Option<&mut Shard>,
        shard_idx: usize,
        client_id: u64,
        seq: u64,
        op: MetaOp,
        now: VirtualTime,
        log: bool,
    ) -> Response {
        let previously_failed =
            shard.failed.get(&client_id).map(|s| s.contains(&seq)).unwrap_or(false);
        if shard.is_duplicate(client_id, seq) {
            // replayed duplicate: already applied — answer success again
            let version =
                self.fs.read().unwrap().stat(op.path()).map(|a| a.version).unwrap_or(0);
            return Response::Applied { seq, new_version: version };
        }
        // modeled home-disk write service for bulk payloads happens under
        // the shard lock — a real home disk serializes writes to the same
        // subtree exactly like this
        match &op {
            MetaOp::WriteFull { data, .. } => self.io_wait(data.len() as u64),
            MetaOp::WriteDelta { blocks, .. } => {
                self.io_wait(blocks.iter().map(|(_, b)| b.len() as u64).sum::<u64>())
            }
            _ => {}
        }
        let result: Result<Vec<(String, bool)>, FsError> = match &op {
            MetaOp::Mkdir { path } => {
                self.fs.write().unwrap().mkdir_p(path, now).map(|_| vec![(path.clone(), false)])
            }
            MetaOp::Rmdir { path } => {
                self.fs.write().unwrap().rmdir(path, now).map(|_| vec![(path.clone(), true)])
            }
            MetaOp::Create { path } => {
                let r = match self.fs.write().unwrap().create(path, now) {
                    Ok(_) => Ok(()),
                    Err(FsError::Exists(_)) => Ok(()), // create is idempotent
                    Err(e) => Err(e),
                };
                r.map(|_| vec![(path.clone(), false)])
            }
            MetaOp::Unlink { path } => {
                self.fs.write().unwrap().unlink(path, now).map(|_| vec![(path.clone(), true)])
            }
            MetaOp::Rename { from, to } => self
                .fs
                .write()
                .unwrap()
                .rename(from, to, now)
                .map(|_| vec![(from.clone(), true), (to.clone(), false)]),
            MetaOp::Truncate { path, size } => self
                .fs
                .write()
                .unwrap()
                .truncate(path, *size, now)
                .map(|_| vec![(path.clone(), false)]),
            MetaOp::SetMode { path, mode } => self
                .fs
                .write()
                .unwrap()
                .set_mode(path, *mode, now)
                .map(|_| vec![(path.clone(), false)]),
            MetaOp::WriteFull { path, data, digests, base_version } => {
                let mut touched = vec![(path.clone(), false)];
                if *base_version > 0 && !digests.is_empty() {
                    let attr = self.fs.read().unwrap().stat(path).ok();
                    if let Some(attr) = attr {
                        if attr.version != *base_version
                            && self.digests_in(shard, path, attr.version) != *digests
                        {
                            // a disconnected-time write raced a home-side
                            // edit the client never saw: last close wins,
                            // but the losing copy is preserved beside the
                            // file instead of silently dropped (§2.5).
                            // Digest-equal content is not a conflict —
                            // nothing would be lost. The loser is COPIED
                            // aside (not renamed): the original inode must
                            // keep its version so the write below bumps it
                            // monotonically — a recreated inode would
                            // restart at a low version and other clients'
                            // `version < new_version` invalidation gate
                            // would dismiss the callback and serve stale.
                            // client_id keeps names from colliding when
                            // two clients' independent per-client seqs
                            // conflict on the same path
                            let conflict = format!(
                                "{}.xufs-conflict-{client_id}-{seq}",
                                vpath::normalize(path)
                            );
                            let loser =
                                self.fs.read().unwrap().read(path).map(|d| d.to_vec());
                            if let Ok(loser) = loser {
                                if self.fs.write().unwrap().write(&conflict, &loser, now).is_ok()
                                {
                                    self.metrics.incr(names::CONFLICT_FILES);
                                    touched.push((conflict, false));
                                }
                            }
                        }
                    }
                }
                let r = self.fs.write().unwrap().write(path, data, now);
                if r.is_ok() && !digests.is_empty() {
                    let v = self.fs.read().unwrap().stat(path).map(|a| a.version).unwrap_or(0);
                    shard.digest_cache.insert(vpath::normalize(path), (v, digests.clone()));
                }
                r.map(|_| touched)
            }
            MetaOp::WriteDelta { path, total_size, base_version, blocks, digests } => self
                .apply_delta(shard, path, *total_size, *base_version, blocks, digests, now)
                .map(|_| vec![(path.clone(), false)]),
            // WriteRef is replication-internal: the ingest path
            // materializes it back into a WriteFull BEFORE apply, so one
            // arriving here came straight from a client — refuse it.
            MetaOp::WriteRef { .. } => Err(FsError::Invalid(
                "WriteRef is a replication-log spill, not a client op".into(),
            )),
        };
        match result {
            Ok(touched) => {
                // max(): a successful retry of a previously-failed low seq
                // must not regress the watermark
                let wm = shard.applied.entry(client_id).or_insert(0);
                *wm = (*wm).max(seq);
                if previously_failed {
                    if let Some(s) = shard.failed.get_mut(&client_id) {
                        s.remove(&seq);
                    }
                }
                let version =
                    self.fs.read().unwrap().stat(op.path()).map(|a| a.version).unwrap_or(0);
                for (path, removed) in touched {
                    if removed {
                        shard.purge_digests(&vpath::normalize(&path));
                        self.notify_removed_in(shard, &path, Some(client_id));
                    } else {
                        let v = self
                            .fs
                            .read()
                            .unwrap()
                            .stat(&path)
                            .map(|a| a.version)
                            .unwrap_or(version);
                        self.notify_change_in(shard, &path, v, Some(client_id));
                    }
                }
                // a rename target's stale digest-cache entry must go:
                // the moved inode KEEPS its version, so a version
                // collision with the replaced file would otherwise serve
                // the old content's digests for the new bytes
                if let MetaOp::Rename { to, .. } = &op {
                    let to_key = vpath::normalize(to);
                    match to_shard {
                        Some(ts) => ts.purge_digests(&to_key),
                        None => shard.purge_digests(&to_key),
                    }
                }
                // record the genuine application in the replication log
                // while the shard guard is still held, so log order
                // matches per-shard apply order (DESIGN.md §2.7). A
                // rename's meaningful version lives at the TARGET (the
                // moved inode keeps it; the source is gone) — the I4
                // watermark oracle in the explorer leans on this.
                if log && self.replication_enabled() {
                    let logged_version = match &op {
                        MetaOp::Rename { to, .. } => self
                            .fs
                            .read()
                            .unwrap()
                            .stat(to)
                            .map(|a| a.version)
                            .unwrap_or(version),
                        _ => version,
                    };
                    self.log_record(
                        shard_idx,
                        ReplPayload::Op { client_id, seq, new_version: logged_version, op },
                    );
                }
                self.maybe_gc();
                Response::Applied { seq, new_version: version }
            }
            Err(e) => {
                let set = shard.failed.entry(client_id).or_default();
                set.insert(seq);
                while set.len() > Self::MAX_FAILED_SEQS {
                    set.pop_first();
                }
                // semantic failures replicate too: the failed-seq set is
                // part of the idempotence watermark's meaning (a replay
                // of this seq must retry for real, not be false-acked —
                // on the secondary exactly as on the primary)
                if log && self.replication_enabled() {
                    self.log_record(
                        shard_idx,
                        ReplPayload::Failed { client_id, seq, path: op.path().to_string() },
                    );
                }
                err_resp(&e)
            }
        }
    }

    /// Apply a delta writeback: only valid against the exact base version
    /// the client diffed from; otherwise the client must fall back to a
    /// full write (the server's copy changed concurrently).
    #[allow(clippy::too_many_arguments)]
    fn apply_delta(
        &self,
        shard: &mut Shard,
        path: &str,
        total_size: u64,
        base_version: u64,
        blocks: &[(u32, Vec<u8>)],
        digests: &[i32],
        now: VirtualTime,
    ) -> Result<(), FsError> {
        // patch a copy of the base outside the store's write section (the
        // write lock is global; only the final install holds it)
        let mut data = {
            let fs = self.fs.read().unwrap();
            let attr = fs.stat(path)?;
            if attr.version != base_version {
                return Err(FsError::Stale(format!(
                    "delta base version {base_version} != server version {}",
                    attr.version
                )));
            }
            fs.read(path)?.to_vec()
        };
        data.resize(total_size as usize, 0);
        for (raw_idx, raw_payload) in blocks {
            // transport v2 (DESIGN.md §2.12): a block index carrying the
            // compression bit holds a flag-byte-framed payload; legacy
            // raw blocks pass through decode_block untouched, so old and
            // new clients share this one path
            let Some((idx, payload)) =
                transfer::compress::decode_block(*raw_idx, raw_payload, self.block_bytes)
            else {
                return Err(FsError::Invalid(format!(
                    "delta block {raw_idx:#x} carries an undecodable compressed payload"
                )));
            };
            let start = idx as usize * self.block_bytes;
            let end = (start + payload.len()).min(data.len());
            if start > data.len() {
                return Err(FsError::Invalid(format!("delta block {idx} beyond file size")));
            }
            data[start..end].copy_from_slice(&payload[..end - start]);
        }
        // the path's shard lock is held, so the version cannot have moved
        // since the gate above (same-path ops serialize per shard)
        self.fs.write().unwrap().write(path, &data, now)?;
        if !digests.is_empty() {
            let v = self.fs.read().unwrap().stat(path).map(|a| a.version).unwrap_or(0);
            shard.digest_cache.insert(vpath::normalize(path), (v, digests.to_vec()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::LockKind;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs(s)
    }

    fn server() -> FileServer {
        let mut fs = FileStore::default();
        fs.mkdir_p("/home/user", t(0.0)).unwrap();
        fs.write("/home/user/a.txt", b"hello world", t(0.0)).unwrap();
        fs.write("/home/user/b.dat", &[7u8; 200_000], t(0.0)).unwrap();
        FileServer::new(
            fs,
            DiskModel::new(200.0e6, 0.002),
            Arc::new(DigestEngine::native(Metrics::new())),
            65536,
            30.0,
            4,
            Metrics::new(),
            ChunkstoreConfig::default(),
        )
    }

    #[test]
    fn stat_and_readdir() {
        let s = server();
        match s.handle(1, Request::Stat { path: "/home/user/a.txt".into() }, t(1.0)) {
            Response::Attr { attr } => assert_eq!(attr.size, 11),
            r => panic!("{r:?}"),
        }
        match s.handle(1, Request::ReadDir { path: "/home/user".into() }, t(1.0)) {
            Response::Dir { entries } => {
                assert_eq!(entries.len(), 2);
                assert_eq!(entries[0].name, "a.txt");
            }
            r => panic!("{r:?}"),
        }
        match s.handle(1, Request::Stat { path: "/missing".into() }, t(1.0)) {
            Response::Err { code, .. } => assert_eq!(code, 2),
            r => panic!("{r:?}"),
        }
    }

    #[test]
    fn fetch_includes_verifiable_digests() {
        let s = server();
        match s.handle(1, Request::Fetch { path: "/home/user/b.dat".into(), min_version: 0 }, t(1.0)) {
            Response::File { image } => {
                assert_eq!(image.data.len(), 200_000);
                assert_eq!(image.digests.len(), 4); // ceil(200000/65536)
                let engine = DigestEngine::native(Metrics::new());
                assert_eq!(engine.digests(&image.data, 65536), image.digests);
            }
            r => panic!("{r:?}"),
        }
    }

    #[test]
    fn digest_cache_reused_until_version_changes() {
        let mut s = server();
        s.handle(1, Request::Fetch { path: "/home/user/a.txt".into(), min_version: 0 }, t(1.0));
        let m = Metrics::new();
        let e = Arc::new(DigestEngine::native(m.clone()));
        s.engine = e;
        // same version: cache hit, engine not consulted
        s.handle(1, Request::Fetch { path: "/home/user/a.txt".into(), min_version: 0 }, t(2.0));
        assert_eq!(m.counter(names::DIGEST_CALLS), 0);
        s.local_write("/home/user/a.txt", b"changed", t(3.0)).unwrap();
        s.handle(1, Request::Fetch { path: "/home/user/a.txt".into(), min_version: 0 }, t(4.0));
        assert_eq!(m.counter(names::DIGEST_CALLS), 1);
    }

    #[test]
    fn fetch_range_serves_block_extents_with_digests() {
        let s = server();
        // whole-file digests (fills the digest cache)
        let whole = match s.handle(1, Request::Fetch { path: "/home/user/b.dat".into(), min_version: 0 }, t(1.0)) {
            Response::File { image } => image,
            r => panic!("{r:?}"),
        };
        let v = s.home().stat("/home/user/b.dat").unwrap().version;
        // a mid-file byte range comes back as the covering blocks, each
        // carrying the digest the whole-file fetch reported
        let r = s.handle(
            1,
            Request::FetchRange {
                path: "/home/user/b.dat".into(),
                offset: 65536 + 10,
                len: 65536,
                expect_version: v,
            },
            t(2.0),
        );
        let Response::FileBlocks { version, extents } = r else { panic!("{r:?}") };
        assert_eq!(version, v);
        assert_eq!(extents.len(), 2); // blocks 1 and 2 cover the range
        assert_eq!(extents[0].index, 1);
        assert_eq!(extents[1].index, 2);
        for x in &extents {
            let start = x.index as usize * 65536;
            assert_eq!(x.data, whole.data[start..start + x.data.len()]);
            assert_eq!(x.digest, whole.digests[x.index as usize]);
        }
        // the tail block is short, clamped to the file size
        let r = s.handle(
            1,
            Request::FetchRange {
                path: "/home/user/b.dat".into(),
                offset: 199_000,
                len: 1 << 20,
                expect_version: v,
            },
            t(3.0),
        );
        let Response::FileBlocks { extents, .. } = r else { panic!("{r:?}") };
        assert_eq!(extents.len(), 1);
        assert_eq!(extents[0].index, 3);
        assert_eq!(extents[0].data.len(), 200_000 - 3 * 65536);
        // out-of-range offsets yield an empty (not erroneous) reply
        let r = s.handle(
            1,
            Request::FetchRange {
                path: "/home/user/b.dat".into(),
                offset: 10 << 20,
                len: 4096,
                expect_version: v,
            },
            t(4.0),
        );
        assert!(matches!(r, Response::FileBlocks { ref extents, .. } if extents.is_empty()), "{r:?}");
    }

    #[test]
    fn apply_is_idempotent_per_client() {
        let s = server();
        let op = MetaOp::WriteFull {
            path: "/home/user/new".into(),
            data: b"v1".to_vec(),
            digests: vec![],
            base_version: 0,
        };
        let r1 = s.handle(1, Request::Apply { seq: 1, op: op.clone() }, t(1.0));
        assert!(matches!(r1, Response::Applied { seq: 1, .. }));
        let v1 = s.home().stat("/home/user/new").unwrap().version;
        // replay of the same seq must not bump the version
        let r2 = s.handle(1, Request::Apply { seq: 1, op }, t(2.0));
        assert!(matches!(r2, Response::Applied { seq: 1, .. }));
        assert_eq!(s.home().stat("/home/user/new").unwrap().version, v1);
    }

    #[test]
    fn compound_applies_in_order_with_per_op_status() {
        let s = server();
        let r = s.handle(
            1,
            Request::Compound {
                ops: vec![
                    CompoundOp::Apply { seq: 1, op: MetaOp::Mkdir { path: "/home/user/new".into() } },
                    CompoundOp::Apply {
                        seq: 2,
                        op: MetaOp::WriteFull {
                            path: "/home/user/new/f.txt".into(),
                            data: b"compound".to_vec(),
                            digests: vec![],
                            base_version: 0,
                        },
                    },
                    // semantic failure mid-batch must not stop later ops
                    CompoundOp::Apply { seq: 3, op: MetaOp::Unlink { path: "/home/user/ghost".into() } },
                    CompoundOp::Stat { path: "/home/user/new/f.txt".into() },
                ],
            },
            t(1.0),
        );
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert_eq!(replies.len(), 4);
        assert!(matches!(replies[0], Response::Applied { seq: 1, .. }));
        assert!(matches!(replies[1], Response::Applied { seq: 2, .. }));
        assert!(matches!(replies[2], Response::Err { code: 2, .. }));
        assert!(matches!(&replies[3], Response::Attr { attr } if attr.size == 8));
        assert_eq!(s.home().read("/home/user/new/f.txt").unwrap(), b"compound");
        // a failed op does not advance the idempotence watermark past it:
        // replaying seq 3 after fixing the cause still applies
        s.home_mut().write("/home/user/ghost", b"x", t(2.0)).unwrap();
        let r = s.handle(
            1,
            Request::Compound {
                ops: vec![CompoundOp::Apply { seq: 3, op: MetaOp::Unlink { path: "/home/user/ghost".into() } }],
            },
            t(3.0),
        );
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert!(matches!(replies[0], Response::Applied { seq: 3, .. }), "{replies:?}");
        assert!(!s.home().exists("/home/user/ghost"));
    }

    #[test]
    fn compound_replay_retries_failed_ops_not_false_acks() {
        let s = server();
        let ops = vec![
            // fails (no such file) while the NEXT op advances the watermark
            CompoundOp::Apply { seq: 1, op: MetaOp::Unlink { path: "/home/user/ghost".into() } },
            CompoundOp::Apply { seq: 2, op: MetaOp::Mkdir { path: "/home/user/d2".into() } },
        ];
        let r = s.handle(1, Request::Compound { ops: ops.clone() }, t(1.0));
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert!(matches!(replies[0], Response::Err { code: 2, .. }));
        assert!(matches!(replies[1], Response::Applied { seq: 2, .. }));
        // the reply frame is lost; the client replays the whole compound.
        // The failed seq must fail AGAIN — answering it as a duplicate
        // would falsely ack a write that never landed.
        let r = s.handle(1, Request::Compound { ops }, t(2.0));
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert!(matches!(replies[0], Response::Err { code: 2, .. }), "{replies:?}");
        assert!(matches!(replies[1], Response::Applied { seq: 2, .. }));
        // once the cause is fixed, a retry under the SAME seq applies...
        s.home_mut().write("/home/user/ghost", b"x", t(3.0)).unwrap();
        let r = s.handle(
            1,
            Request::Apply { seq: 1, op: MetaOp::Unlink { path: "/home/user/ghost".into() } },
            t(4.0),
        );
        assert!(matches!(r, Response::Applied { seq: 1, .. }), "{r:?}");
        assert!(!s.home().exists("/home/user/ghost"));
        // ...and the watermark did not regress: seq 2 is still a duplicate
        let before = s.home().stat("/home/user/d2").unwrap().version;
        let r = s.handle(
            1,
            Request::Apply { seq: 2, op: MetaOp::Mkdir { path: "/home/user/d2".into() } },
            t(5.0),
        );
        assert!(matches!(r, Response::Applied { seq: 2, .. }));
        assert_eq!(s.home().stat("/home/user/d2").unwrap().version, before);
    }

    #[test]
    fn compound_replay_is_idempotent() {
        let s = server();
        let ops = vec![
            CompoundOp::Apply {
                seq: 1,
                op: MetaOp::WriteFull {
                    path: "/home/user/q".into(),
                    data: b"v".to_vec(),
                    digests: vec![],
                    base_version: 0,
                },
            },
            CompoundOp::Apply { seq: 2, op: MetaOp::Mkdir { path: "/home/user/d".into() } },
        ];
        s.handle(1, Request::Compound { ops: ops.clone() }, t(1.0));
        let v1 = s.home().stat("/home/user/q").unwrap().version;
        // whole-compound replay after a lost reply: versions must not move
        let r = s.handle(1, Request::Compound { ops }, t(2.0));
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert!(replies.iter().all(|r| matches!(r, Response::Applied { .. })));
        assert_eq!(s.home().stat("/home/user/q").unwrap().version, v1);
    }

    #[test]
    fn apply_notifies_other_clients_not_originator() {
        let s = server();
        let ch1 = NotifyChannel::new();
        let ch2 = NotifyChannel::new();
        s.attach_channel(1, ch1.clone());
        s.attach_channel(2, ch2.clone());
        s.handle(1, Request::RegisterCallback { root: "/home/user".into(), client_id: 1 }, t(0.0));
        s.handle(2, Request::RegisterCallback { root: "/home/user".into(), client_id: 2 }, t(0.0));
        let op = MetaOp::WriteFull {
            path: "/home/user/a.txt".into(),
            data: b"x".to_vec(),
            digests: vec![],
            base_version: 0,
        };
        s.handle(1, Request::Apply { seq: 1, op }, t(1.0));
        assert_eq!(ch1.pending(), 0, "originator must not be invalidated");
        let evs = ch2.drain();
        assert_eq!(evs.len(), 1);
        assert!(matches!(&evs[0], NotifyEvent::Invalidate { path, .. } if path == "/home/user/a.txt"));
    }

    #[test]
    fn local_write_invalidates_everyone() {
        let s = server();
        let ch = NotifyChannel::new();
        s.attach_channel(1, ch.clone());
        s.handle(1, Request::RegisterCallback { root: "/home/user".into(), client_id: 1 }, t(0.0));
        s.local_write("/home/user/a.txt", b"edited at home", t(1.0)).unwrap();
        assert_eq!(ch.pending(), 1);
        s.local_unlink("/home/user/a.txt", t(2.0)).unwrap();
        let evs = ch.drain();
        assert!(matches!(&evs[1], NotifyEvent::Removed { path } if path == "/home/user/a.txt"));
    }

    #[test]
    fn delta_against_stale_base_rejected() {
        let s = server();
        let base = s.home().stat("/home/user/b.dat").unwrap().version;
        s.local_write("/home/user/b.dat", &[9u8; 100], t(1.0)).unwrap();
        let r = s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteDelta {
                    path: "/home/user/b.dat".into(),
                    total_size: 100,
                    base_version: base,
                    blocks: vec![(0, vec![1; 64])],
                    digests: vec![],
                },
            },
            t(2.0),
        );
        assert!(matches!(r, Response::Err { code: 116, .. }), "{r:?}");
    }

    #[test]
    fn delta_applies_blocks() {
        let s = server();
        let base = s.home().stat("/home/user/b.dat").unwrap().version;
        let mut expect = s.home().read("/home/user/b.dat").unwrap().to_vec();
        let blk = vec![0xABu8; 65536];
        expect[65536..131072].copy_from_slice(&blk);
        let r = s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteDelta {
                    path: "/home/user/b.dat".into(),
                    total_size: 200_000,
                    base_version: base,
                    blocks: vec![(1, blk)],
                    digests: vec![],
                },
            },
            t(2.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        assert_eq!(s.home().read("/home/user/b.dat").unwrap(), &expect[..]);
    }

    #[test]
    fn compressed_delta_applies_byte_identically() {
        let s = server();
        let base = s.home().stat("/home/user/b.dat").unwrap().version;
        let mut expect = s.home().read("/home/user/b.dat").unwrap().to_vec();
        let blk = vec![0xCDu8; 65536];
        expect[65536..131072].copy_from_slice(&blk);
        let mut op = MetaOp::WriteDelta {
            path: "/home/user/b.dat".into(),
            total_size: 200_000,
            base_version: base,
            blocks: vec![(1, blk)],
            digests: vec![],
        };
        transfer::compress::compress_delta_op(&mut op, &Metrics::new());
        // the run block really was framed, so apply exercises the decoder
        if let MetaOp::WriteDelta { blocks, .. } = &op {
            assert_ne!(blocks[0].0 & transfer::compress::COMPRESSED_IDX_BIT, 0);
            assert!(blocks[0].1.len() < 1000, "framed to {} bytes", blocks[0].1.len());
        }
        let r = s.handle(1, Request::Apply { seq: 1, op }, t(2.0));
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        assert_eq!(s.home().read("/home/user/b.dat").unwrap(), &expect[..]);
        // an undecodable compressed frame is refused, never applied
        let v = s.home().stat("/home/user/b.dat").unwrap().version;
        let bad = MetaOp::WriteDelta {
            path: "/home/user/b.dat".into(),
            total_size: 200_000,
            base_version: v,
            blocks: vec![(transfer::compress::COMPRESSED_IDX_BIT | 1, vec![99, 1, 2])],
            digests: vec![],
        };
        let r = s.handle(1, Request::Apply { seq: 2, op: bad }, t(3.0));
        assert!(matches!(r, Response::Err { .. }), "{r:?}");
        assert_eq!(s.home().read("/home/user/b.dat").unwrap(), &expect[..]);
    }

    #[test]
    fn crash_refuses_and_restart_recovers() {
        let s = server();
        let ch = NotifyChannel::new();
        s.attach_channel(1, ch.clone());
        s.handle(1, Request::RegisterCallback { root: "/".into(), client_id: 1 }, t(0.0));
        s.handle(1, Request::LockAcquire { path: "/home/user/a.txt".into(), kind: LockKind::Exclusive, owner: 1 }, t(0.0));
        s.crash();
        assert!(!ch.is_connected());
        assert!(matches!(s.handle(1, Request::Ping, t(1.0)), Response::Err { code: 111, .. }));
        s.restart();
        assert!(matches!(s.handle(1, Request::Ping, t(2.0)), Response::Pong));
        // lock table was lost in the crash: a new owner can acquire
        let r = s.handle(
            2,
            Request::LockAcquire { path: "/home/user/a.txt".into(), kind: LockKind::Exclusive, owner: 2 },
            t(3.0),
        );
        assert!(matches!(r, Response::LockGranted { .. }));
    }

    #[test]
    fn lock_lifecycle_over_protocol() {
        let s = server();
        let r = s.handle(
            1,
            Request::LockAcquire { path: "/f".into(), kind: LockKind::Exclusive, owner: 10 },
            t(0.0),
        );
        let Response::LockGranted { token, lease_ns } = r else { panic!("{r:?}") };
        assert_eq!(lease_ns, 30_000_000_000);
        assert!(matches!(
            s.handle(2, Request::LockAcquire { path: "/f".into(), kind: LockKind::Shared, owner: 11 }, t(1.0)),
            Response::LockDenied { holder: 10 }
        ));
        assert!(matches!(
            s.handle(1, Request::LockRenew { token, owner: 10 }, t(10.0)),
            Response::LockGranted { .. }
        ));
        assert!(matches!(s.handle(1, Request::LockRelease { token, owner: 10 }, t(11.0)), Response::Released));
        assert!(matches!(
            s.handle(2, Request::LockAcquire { path: "/f".into(), kind: LockKind::Shared, owner: 11 }, t(12.0)),
            Response::LockGranted { .. }
        ));
    }

    #[test]
    fn rename_notifies_both_paths() {
        let s = server();
        let ch = NotifyChannel::new();
        s.attach_channel(2, ch.clone());
        s.handle(2, Request::RegisterCallback { root: "/home/user".into(), client_id: 2 }, t(0.0));
        s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::Rename { from: "/home/user/a.txt".into(), to: "/home/user/c.txt".into() },
            },
            t(1.0),
        );
        let evs = ch.drain();
        assert_eq!(evs.len(), 2);
        assert!(matches!(&evs[0], NotifyEvent::Removed { path } if path == "/home/user/a.txt"));
        assert!(matches!(&evs[1], NotifyEvent::Invalidate { path, .. } if path == "/home/user/c.txt"));
    }

    // ----- sharding-specific coverage (DESIGN.md §2.6) -----

    /// Find two paths under `dir` that hash to DIFFERENT shards (and two
    /// that hash to the same), by probing candidate names.
    fn cross_shard_pair(s: &FileServer, dir: &str) -> (String, String) {
        let first = format!("{dir}/x0");
        let base = s.shard_of(&first);
        for i in 1..256 {
            let cand = format!("{dir}/x{i}");
            if s.shard_of(&cand) != base {
                return (first, cand);
            }
        }
        panic!("no cross-shard pair in 256 candidates");
    }

    #[test]
    fn routing_is_deterministic_and_normalized() {
        let s = server();
        assert_eq!(s.shard_count(), 4);
        assert_eq!(s.shard_of("/home/user/a.txt"), s.shard_of("/home//user/./a.txt"));
        // every path routes inside the shard vector
        for i in 0..64 {
            assert!(s.shard_of(&format!("/p{i}")) < s.shard_count());
        }
    }

    #[test]
    fn cross_shard_rename_moves_and_counts() {
        let s = server();
        let (from, to) = cross_shard_pair(&s, "/home/user");
        s.home_mut().write(&from, b"payload", t(0.0)).unwrap();
        let before = s.metrics.counter(names::CROSS_SHARD_OPS);
        let r = s.handle(
            1,
            Request::Apply { seq: 1, op: MetaOp::Rename { from: from.clone(), to: to.clone() } },
            t(1.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        assert!(!s.home().exists(&from));
        assert_eq!(s.home().read(&to).unwrap(), b"payload");
        assert!(
            s.metrics.counter(names::CROSS_SHARD_OPS) > before,
            "a rename spanning shards takes the ordered two-shard path"
        );
        // replay stays idempotent across the two-shard path
        let v = s.home().stat(&to).unwrap().version;
        let r = s.handle(
            1,
            Request::Apply { seq: 1, op: MetaOp::Rename { from, to: to.clone() } },
            t(2.0),
        );
        assert!(matches!(r, Response::Applied { seq: 1, .. }), "{r:?}");
        assert_eq!(s.home().stat(&to).unwrap().version, v, "duplicate must not re-apply");
    }

    #[test]
    fn rename_over_existing_file_drops_stale_target_digests() {
        let s = server();
        // a SAME-shard (from, to) pair under /home/user — exercises the
        // non-cross-shard arm of the rename digest-cache invalidation
        let to = "/home/user/s0".to_string();
        let mut from = None;
        for i in 1..256 {
            let cand = format!("/home/user/s{i}");
            if s.shard_of(&cand) == s.shard_of(&to) {
                from = Some(cand);
                break;
            }
        }
        let from = from.expect("a same-shard sibling in 256 candidates");
        s.home_mut().write(&to, b"old target content", t(0.0)).unwrap();
        s.home_mut().write(&from, b"new content", t(0.0)).unwrap();
        let v_cached = s.home().stat(&to).unwrap().version;
        // cache the target's digests at its current version
        assert!(matches!(
            s.handle(1, Request::FetchMeta { path: to.clone(), min_version: 0 }, t(1.0)),
            Response::FileMeta { .. }
        ));
        // rename over it: the moved inode KEEPS its version, which here
        // collides with the version the cache entry is keyed by
        let r = s.handle(
            1,
            Request::Apply { seq: 1, op: MetaOp::Rename { from: from.clone(), to: to.clone() } },
            t(2.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        // the scenario really is a version collision (the moved inode
        // kept its version, equal to the cached entry's key)
        assert_eq!(s.home().stat(&to).unwrap().version, v_cached);
        // the re-fetch must serve digests of the NEW content, not the
        // stale cached vector
        let r = s.handle(1, Request::FetchMeta { path: to.clone(), min_version: 0 }, t(3.0));
        let Response::FileMeta { digests, .. } = r else { panic!("{r:?}") };
        let engine = DigestEngine::native(Metrics::new());
        assert_eq!(digests, engine.digests(b"new content", 65536));
    }

    #[test]
    fn directory_rename_purges_descendant_digests() {
        let s = server();
        s.home_mut().mkdir_p("/home/user/dir", t(0.0)).unwrap();
        s.home_mut().write("/home/user/dir/f", b"old content", t(0.0)).unwrap();
        // cache the child's digests (keyed by its current version)
        assert!(matches!(
            s.handle(1, Request::FetchMeta { path: "/home/user/dir/f".into(), min_version: 0 }, t(1.0)),
            Response::FileMeta { .. }
        ));
        // move the whole directory, then recreate the old path: the new
        // child inode's version restarts and collides with the cached key
        let r = s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::Rename { from: "/home/user/dir".into(), to: "/home/user/dir2".into() },
            },
            t(2.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        let r = s.handle(
            1,
            Request::Apply { seq: 2, op: MetaOp::Mkdir { path: "/home/user/dir".into() } },
            t(3.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        let r = s.handle(
            1,
            Request::Apply {
                seq: 3,
                op: MetaOp::WriteFull {
                    path: "/home/user/dir/f".into(),
                    data: b"new content".to_vec(),
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(4.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        // the dir-rename sweep must have dropped the stale child entry:
        // this serves digests of the NEW content despite the collision
        let r = s.handle(1, Request::FetchMeta { path: "/home/user/dir/f".into(), min_version: 0 }, t(5.0));
        let Response::FileMeta { digests, .. } = r else { panic!("{r:?}") };
        let engine = DigestEngine::native(Metrics::new());
        assert_eq!(digests, engine.digests(b"new content", 65536));
        // and the moved copy still reads correctly under its new path
        assert_eq!(s.home().read("/home/user/dir2/f").unwrap(), b"old content");
    }

    #[test]
    fn watermarks_are_per_path_shard_but_semantically_global() {
        let s = server();
        // ops with ascending seqs land on whatever shards their paths
        // hash to; replaying ANY of them must answer as a duplicate
        for seq in 1..=12u64 {
            let op = MetaOp::WriteFull {
                path: format!("/home/user/w{seq}"),
                data: vec![seq as u8; 64],
                digests: vec![],
                base_version: 0,
            };
            let r = s.handle(7, Request::Apply { seq, op }, t(seq as f64));
            assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        }
        for seq in 1..=12u64 {
            let path = format!("/home/user/w{seq}");
            let v = s.home().stat(&path).unwrap().version;
            let op = MetaOp::WriteFull {
                path: path.clone(),
                data: vec![seq as u8; 64],
                digests: vec![],
                base_version: 0,
            };
            let r = s.handle(7, Request::Apply { seq, op }, t(20.0));
            assert!(matches!(r, Response::Applied { .. }), "{r:?}");
            assert_eq!(s.home().stat(&path).unwrap().version, v, "seq {seq} re-applied");
        }
    }

    #[test]
    fn lock_tokens_route_back_to_their_shard() {
        let s = server();
        // locks on many paths spread over shards; every token must renew
        // and release correctly even though those requests carry no path
        let mut tokens = Vec::new();
        for i in 0..16 {
            let r = s.handle(
                1,
                Request::LockAcquire {
                    path: format!("/home/user/l{i}"),
                    kind: LockKind::Exclusive,
                    owner: 1,
                },
                t(0.0),
            );
            let Response::LockGranted { token, .. } = r else { panic!("{r:?}") };
            tokens.push(token);
        }
        let unique: std::collections::HashSet<u64> = tokens.iter().copied().collect();
        assert_eq!(unique.len(), tokens.len(), "tokens unique across shards");
        for &token in &tokens {
            assert!(matches!(
                s.handle(1, Request::LockRenew { token, owner: 1 }, t(5.0)),
                Response::LockGranted { .. }
            ));
        }
        for &token in &tokens {
            assert!(matches!(
                s.handle(1, Request::LockRelease { token, owner: 1 }, t(6.0)),
                Response::Released
            ));
        }
    }

    // ----- replication (DESIGN.md §2.7) -----

    /// A primary (with the standard test home space) and a secondary
    /// seeded from a snapshot of it, both logging applied ops.
    fn replica_pair() -> (FileServer, FileServer) {
        let s = server();
        s.enable_replication();
        let snap = s.home().clone();
        let sec = FileServer::new(
            snap,
            DiskModel::new(200.0e6, 0.002),
            Arc::new(DigestEngine::native(Metrics::new())),
            65536,
            30.0,
            4,
            Metrics::new(),
            ChunkstoreConfig::default(),
        );
        sec.set_role(Role::Secondary);
        sec.enable_replication();
        (s, sec)
    }

    /// Ship everything past the secondary's watermark in one frame,
    /// filling chunk gaps the way the real shipper does: a ReplicaNeed
    /// answer gets the missing payloads pushed, then the SAME batch
    /// re-sent.
    fn ship_all(primary: &FileServer, sec: &FileServer) {
        let from = sec.repl_ship_seq();
        let recs = primary.repl_records_after(from, usize::MAX);
        let frames = crate::replica::frame_records(&recs);
        let mut r =
            sec.handle(0, Request::Replicate { from: from + 1, frames: frames.clone(), head: 0 }, t(1.0));
        if let Response::ReplicaNeed { digests } = &r {
            let chunks = primary.read_chunks(digests);
            assert_eq!(chunks.len(), digests.len(), "primary must hold every pinned chunk");
            let pr = sec.handle(0, Request::ChunkPush { chunks }, t(1.0));
            assert!(matches!(pr, Response::ChunkAck { .. }), "{pr:?}");
            r = sec.handle(0, Request::Replicate { from: from + 1, frames, head: 0 }, t(1.0));
        }
        assert!(matches!(r, Response::ReplicaAck { .. }), "{r:?}");
    }

    #[test]
    fn secondary_refuses_clients_until_promoted() {
        let (_s, sec) = replica_pair();
        let r = sec.handle(1, Request::Stat { path: "/home/user/a.txt".into() }, t(1.0));
        assert!(matches!(r, Response::Err { code: 112, .. }), "{r:?}");
        // the replication plane stays open
        assert!(matches!(sec.handle(0, Request::Ping, t(1.0)), Response::Pong));
        assert!(matches!(
            sec.handle(0, Request::WatermarkQuery { shard: u32::MAX }, t(1.0)),
            Response::Watermark { watermark: 0, .. }
        ));
        // the explicit Promote flips it into a serving primary
        let r = sec.handle(0, Request::Promote, t(2.0));
        assert!(matches!(r, Response::Promoted { watermark: 0 }), "{r:?}");
        assert_eq!(sec.role(), Role::Primary);
        let r = sec.handle(1, Request::Stat { path: "/home/user/a.txt".into() }, t(3.0));
        assert!(matches!(r, Response::Attr { .. }), "{r:?}");
    }

    #[test]
    fn retired_primary_is_fenced() {
        let s = server();
        s.retire();
        let r = s.handle(1, Request::Stat { path: "/home/user/a.txt".into() }, t(1.0));
        assert!(matches!(r, Response::Err { code: 112, .. }), "{r:?}");
        // fencing survives a crash/restart cycle (the crontab restart of
        // the old primary must NOT resurrect a second writable head)
        s.crash();
        s.restart();
        let r = s.handle(1, Request::Ping, t(2.0));
        assert!(matches!(r, Response::Pong), "{r:?}");
        let r = s.handle(1, Request::ReadDir { path: "/home/user".into() }, t(2.0));
        assert!(matches!(r, Response::Err { code: 112, .. }), "{r:?}");
    }

    #[test]
    fn replication_mirrors_state_versions_and_watermarks() {
        let (s, sec) = replica_pair();
        // a mix of outcomes: success, semantic failure, home-side edit
        let r = s.handle(
            7,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteFull {
                    path: "/home/user/repl.txt".into(),
                    data: b"replicated".to_vec(),
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(1.0),
        );
        assert!(matches!(r, Response::Applied { .. }));
        let r = s.handle(
            7,
            Request::Apply { seq: 2, op: MetaOp::Unlink { path: "/home/user/ghost".into() } },
            t(1.5),
        );
        assert!(matches!(r, Response::Err { code: 2, .. }));
        let r = s.handle(
            7,
            Request::Apply { seq: 3, op: MetaOp::Mkdir { path: "/home/user/d".into() } },
            t(2.0),
        );
        assert!(matches!(r, Response::Applied { .. }));
        s.local_write("/home/user/a.txt", b"edited at home", t(2.5)).unwrap();
        assert_eq!(s.repl_ship_seq(), 4, "3 client outcomes + 1 local edit logged");

        ship_all(&s, &sec);
        assert_eq!(sec.repl_ship_seq(), 4);
        // the mirrored store is byte- and version-identical
        for path in ["/home/user/repl.txt", "/home/user/a.txt", "/home/user/b.dat"] {
            assert_eq!(
                s.home().read(path).map(|d| d.to_vec()),
                sec.home().read(path).map(|d| d.to_vec()),
                "{path} content"
            );
            assert_eq!(
                s.home().stat(path).unwrap().version,
                sec.home().stat(path).unwrap().version,
                "{path} version"
            );
        }
        assert!(sec.home().exists("/home/user/d"));

        // promote, then replay the client's unacked ops: the replicated
        // idempotence watermark answers seq 1/3 as duplicates (no
        // version bump) while the FAILED seq 2 retries for real
        assert!(matches!(sec.handle(0, Request::Promote, t(3.0)), Response::Promoted { .. }));
        let v = sec.home().stat("/home/user/repl.txt").unwrap().version;
        let r = sec.handle(
            7,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteFull {
                    path: "/home/user/repl.txt".into(),
                    data: b"replicated".to_vec(),
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(4.0),
        );
        assert!(matches!(r, Response::Applied { seq: 1, .. }), "{r:?}");
        assert_eq!(sec.home().stat("/home/user/repl.txt").unwrap().version, v, "no re-apply");
        let r = sec.handle(
            7,
            Request::Apply { seq: 2, op: MetaOp::Unlink { path: "/home/user/ghost".into() } },
            t(4.5),
        );
        assert!(
            matches!(r, Response::Err { code: 2, .. }),
            "a replicated FAILED seq must retry for real, not false-ack: {r:?}"
        );
    }

    #[test]
    fn re_ship_is_idempotent_and_gaps_refused() {
        let (s, sec) = replica_pair();
        for seq in 1..=3u64 {
            s.handle(
                9,
                Request::Apply {
                    seq,
                    op: MetaOp::WriteFull {
                        path: format!("/home/user/f{seq}"),
                        data: vec![seq as u8; 32],
                        digests: vec![],
                        base_version: 0,
                    },
                },
                t(seq as f64),
            );
        }
        let recs = s.repl_records_after(0, usize::MAX);
        let frames = crate::replica::frame_records(&recs);
        // the writes shipped by reference: the first delivery names
        // chunks the secondary does not hold yet — NOTHING applies...
        let r = sec.handle(0, Request::Replicate { from: 1, frames: frames.clone(), head: 0 }, t(4.5));
        let Response::ReplicaNeed { digests } = r else { panic!("{r:?}") };
        assert!(!digests.is_empty());
        assert_eq!(sec.repl_ship_seq(), 0, "a needy batch must not partially apply");
        // ...the pushed payloads fill the gap and the re-send applies
        let chunks = s.read_chunks(&digests);
        let r = sec.handle(0, Request::ChunkPush { chunks }, t(4.6));
        assert!(matches!(r, Response::ChunkAck { .. }), "{r:?}");
        let r = sec.handle(0, Request::Replicate { from: 1, frames: frames.clone(), head: 0 }, t(5.0));
        assert!(matches!(r, Response::ReplicaAck { watermark: 3 }), "{r:?}");
        let v = sec.home().stat("/home/user/f1").unwrap().version;
        // ...a duplicate delivery (lost ack) is skipped wholesale
        let r = sec.handle(0, Request::Replicate { from: 1, frames, head: 0 }, t(6.0));
        assert!(matches!(r, Response::ReplicaAck { watermark: 3 }), "{r:?}");
        assert_eq!(sec.home().stat("/home/user/f1").unwrap().version, v, "no double-apply");
        // a gapped batch is refused, watermark unmoved
        let gap = crate::replica::frame_records(&[ReplRecord {
            ship_seq: 9,
            shard: 0,
            payload: ReplPayload::Local { op: MetaOp::Unlink { path: "/home/user/f1".into() } },
        }]);
        let r = sec.handle(0, Request::Replicate { from: 9, frames: gap, head: 0 }, t(7.0));
        assert!(matches!(r, Response::Err { .. }), "{r:?}");
        assert_eq!(sec.repl_ship_seq(), 3);
        // a tampered batch is refused before anything applies
        let mut bad = crate::replica::frame_records(&s.repl_records_after(0, 1));
        let n = bad.len();
        bad[n - 1] ^= 0xFF;
        let r = sec.handle(0, Request::Replicate { from: 1, frames: bad, head: 0 }, t(8.0));
        assert!(matches!(r, Response::Err { code: 74, .. }), "{r:?}");
    }

    #[test]
    fn per_shard_watermarks_track_routed_records() {
        let (s, sec) = replica_pair();
        let path = "/home/user/wshard".to_string();
        s.handle(
            3,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteFull {
                    path: path.clone(),
                    data: b"x".to_vec(),
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(1.0),
        );
        ship_all(&s, &sec);
        let shard = s.shard_of(&path) as u32;
        let r = sec.handle(0, Request::WatermarkQuery { shard }, t(2.0));
        let Response::Watermark { watermark, .. } = r else { panic!("{r:?}") };
        assert_eq!(watermark, 1, "the routed shard's watermark advanced");
        // an unrouted shard stays at 0; the global view reads 1
        let other = (shard + 1) % 4;
        if s.shard_of(&path) != other as usize {
            let r = sec.handle(0, Request::WatermarkQuery { shard: other }, t(2.0));
            assert!(matches!(r, Response::Watermark { watermark: 0, .. }), "{r:?}");
        }
        let r = sec.handle(0, Request::WatermarkQuery { shard: u32::MAX }, t(2.0));
        assert!(matches!(r, Response::Watermark { watermark: 1, .. }), "{r:?}");
    }

    #[test]
    fn shards_1_is_the_single_lock_ablation() {
        let fs = {
            let mut fs = FileStore::default();
            fs.mkdir_p("/home/user", t(0.0)).unwrap();
            fs
        };
        let s = FileServer::new(
            fs,
            DiskModel::new(200.0e6, 0.002),
            Arc::new(DigestEngine::native(Metrics::new())),
            65536,
            30.0,
            1,
            Metrics::new(),
            ChunkstoreConfig::default(),
        );
        assert_eq!(s.shard_count(), 1);
        for i in 0..8 {
            assert_eq!(s.shard_of(&format!("/home/user/f{i}")), 0);
        }
        let r = s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteFull {
                    path: "/home/user/one".into(),
                    data: b"x".to_vec(),
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(1.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
    }

    // ----- chunk substrate (DESIGN.md §2.8) -----

    #[test]
    fn snapshot_create_and_versioned_reads_over_protocol() {
        let s = server();
        let r = s.handle(1, Request::SnapshotCreate, t(1.0));
        let Response::SnapshotCreated { id } = r else { panic!("{r:?}") };
        assert_eq!(id, 1);
        // live mutation after the cut
        let r = s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteFull {
                    path: "/home/user/a.txt".into(),
                    data: b"rewritten since the snapshot".to_vec(),
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(2.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        // the versioned view serves the frozen image; the live path the
        // new bytes
        let snap_path = format!("/home/user/a.txt@v{id}");
        match s.handle(1, Request::Stat { path: snap_path.clone() }, t(3.0)) {
            Response::Attr { attr } => assert_eq!(attr.size, 11),
            r => panic!("{r:?}"),
        }
        match s.handle(1, Request::Fetch { path: snap_path.clone(), min_version: 0 }, t(3.0)) {
            Response::File { image } => assert_eq!(image.data, b"hello world"),
            r => panic!("{r:?}"),
        }
        match s.handle(1, Request::Fetch { path: "/home/user/a.txt".into(), min_version: 0 }, t(3.0)) {
            Response::File { image } => assert_eq!(image.data, b"rewritten since the snapshot"),
            r => panic!("{r:?}"),
        }
        // snapshot views are read-only — a write through one refuses
        let r = s.handle(
            1,
            Request::Apply {
                seq: 2,
                op: MetaOp::WriteFull {
                    path: snap_path,
                    data: b"nope".to_vec(),
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(4.0),
        );
        assert!(matches!(r, Response::Err { code: 5, .. }), "{r:?}");
    }

    #[test]
    fn log_spills_write_payloads_by_reference() {
        let s = server();
        s.enable_replication();
        let data = vec![0x5Au8; 100_000];
        let r = s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteFull {
                    path: "/home/user/spill".into(),
                    data: data.clone(),
                    digests: vec![11, 22],
                    base_version: 0,
                },
            },
            t(1.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        let recs = s.repl_records_after(0, usize::MAX);
        assert_eq!(recs.len(), 1);
        let ReplPayload::Op { op: MetaOp::WriteRef { size, chunks, digests, base_version, .. }, .. } =
            &recs[0].payload
        else {
            panic!("write not spilled by reference: {recs:?}");
        };
        assert_eq!(*size, data.len() as u64);
        assert_eq!(chunks.len(), data.len().div_ceil(64 * 1024));
        // the op's conflict inputs ride along verbatim
        assert_eq!(digests, &vec![11, 22]);
        assert_eq!(*base_version, 0);
        // the log pins its chunks: one file ref + one log ref each
        let home = s.home();
        let cs = home.chunkstore().expect("chunked substrate");
        for d in chunks {
            assert_eq!(cs.refs(d), 2, "file residency + log pin");
        }
    }

    #[test]
    fn write_ref_from_a_client_is_refused() {
        let s = server();
        let (size, chunks) = s.home().file_chunks("/home/user/a.txt").unwrap();
        let r = s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteRef {
                    path: "/home/user/forged".into(),
                    size,
                    chunks,
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(1.0),
        );
        assert!(matches!(r, Response::Err { code: 5, .. }), "{r:?}");
        assert!(!s.home().exists("/home/user/forged"));
    }

    #[test]
    fn chunk_push_refused_on_primary() {
        let s = server();
        let r = s.handle(0, Request::ChunkPush { chunks: vec![b"x".to_vec()] }, t(1.0));
        assert!(matches!(r, Response::Err { code: 112, .. }), "{r:?}");
    }

    #[test]
    fn acked_prefix_truncation_keeps_shipping_and_promotion_sane() {
        let (s, sec) = replica_pair();
        for seq in 1..=3u64 {
            let r = s.handle(
                9,
                Request::Apply {
                    seq,
                    op: MetaOp::WriteFull {
                        path: format!("/home/user/f{seq}"),
                        data: vec![seq as u8; 32],
                        digests: vec![],
                        base_version: 0,
                    },
                },
                t(seq as f64),
            );
            assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        }
        ship_all(&s, &sec);
        assert_eq!(sec.repl_ship_seq(), 3);
        // truncate the acked prefix: the global position holds, the
        // records are gone, the folded summary keeps their last effects
        assert_eq!(s.repl_truncate_acked(sec.repl_ship_seq()), 3);
        assert_eq!(s.repl_base(), 3);
        assert_eq!(s.repl_ship_seq(), 3);
        assert!(s.repl_records_after(0, usize::MAX).is_empty());
        let (effects, _) = s.repl_truncated_summary();
        assert_eq!(effects.len(), 3);
        assert!(matches!(effects.get("/home/user/f1"), Some(Some(_))));
        // re-truncating at the same watermark is a no-op
        assert_eq!(s.repl_truncate_acked(3), 0);
        // post-truncation appends take the next ship-seq and still ship
        let r = s.handle(
            9,
            Request::Apply {
                seq: 4,
                op: MetaOp::WriteFull {
                    path: "/home/user/f4".into(),
                    data: vec![4u8; 32],
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(4.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        let recs = s.repl_records_after(3, usize::MAX);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].ship_seq, 4);
        ship_all(&s, &sec);
        assert_eq!(sec.repl_ship_seq(), 4);
        // promotion after truncation: replays of TRUNCATED seqs still
        // answer as duplicates (the watermark replicated before the
        // records were dropped)
        let r = sec.handle(0, Request::Promote, t(9.0));
        assert!(matches!(r, Response::Promoted { watermark: 4 }), "{r:?}");
        let v = sec.home().stat("/home/user/f1").unwrap().version;
        let r = sec.handle(
            9,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteFull {
                    path: "/home/user/f1".into(),
                    data: vec![1u8; 32],
                    digests: vec![],
                    base_version: 0,
                },
            },
            t(10.0),
        );
        assert!(matches!(r, Response::Applied { seq: 1, .. }), "{r:?}");
        assert_eq!(sec.home().stat("/home/user/f1").unwrap().version, v, "no re-apply");
    }
}
