//! The XUFS user-space file server (paper §3.1–3.2).
//!
//! Runs on (or beside) the user's personal system, exporting the home
//! space to client sites. Transport-agnostic: [`FileServer::handle`] maps
//! one authenticated request to one response; the simulated deployment
//! calls it directly with modeled WAN delay, the TCP deployment
//! (`coordinator::net`) calls it from connection threads after the USSH
//! challenge-response handshake.
//!
//! Responsibilities:
//! * serve namespace reads (stat/readdir) and whole-file fetches with
//!   per-block digests for integrity + later delta writeback;
//! * apply replayed meta-operations **idempotently** (per-client sequence
//!   numbers — a crashed client can replay its whole queue safely);
//! * fan out change notifications to registered callback channels
//!   (skipping the originating client, whose copy is already current);
//! * grant lock leases via [`lease::LockTable`] and expire orphans;
//! * simulate crash/restart (the paper restarts the server from crontab).

use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

use crate::callback::NotifyChannel;
use crate::homefs::{FileStore, FsError};
use crate::lease::{Acquire, LockTable};
use crate::metrics::{names, Metrics};
use crate::proto::{
    BlockExtent, CompoundOp, DirEntry, FileImage, MetaOp, NotifyEvent, Request, Response, WireAttr,
};
use crate::runtime::DigestEngine;
use crate::simnet::VirtualTime;
use crate::util::path as vpath;
use crate::vdisk::DiskModel;

/// One registered callback (client + subtree root + channel).
#[derive(Debug)]
struct CallbackReg {
    client_id: u64,
    root: String,
    channel: NotifyChannel,
}

/// The user-space file server.
pub struct FileServer {
    fs: FileStore,
    pub disk: DiskModel,
    engine: Arc<DigestEngine>,
    block_bytes: usize,
    locks: LockTable,
    callbacks: Vec<CallbackReg>,
    /// Highest applied meta-op sequence per client (idempotent replay).
    applied: HashMap<u64, u64>,
    /// Seqs at or below the watermark that failed SEMANTICALLY, per
    /// client. A compound advances the watermark past a mid-batch
    /// failure (later ops in the frame still apply), so after a lost
    /// reply the replay of the failed seq must be retried for real —
    /// answering it as a duplicate would falsely ack a write that never
    /// landed. Bounded per client (oldest evicted).
    failed: HashMap<u64, BTreeSet<u64>>,
    /// Digest cache: path -> (version, digests). Fetches of unchanged
    /// files skip recomputation (hot-path optimization, EXPERIMENTS §Perf).
    digest_cache: HashMap<String, (u64, Vec<i32>)>,
    /// Callback channel per client (attached by the transport at connect).
    channel_map: HashMap<u64, NotifyChannel>,
    metrics: Metrics,
    up: bool,
}

impl std::fmt::Debug for FileServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FileServer")
            .field("up", &self.up)
            .field("callbacks", &self.callbacks.len())
            .field("locks", &self.locks.len())
            .finish()
    }
}

fn err_resp(e: &FsError) -> Response {
    let code = match e {
        FsError::NotFound(_) => 2,
        FsError::NotADir(_) => 20,
        FsError::IsADir(_) => 21,
        FsError::Exists(_) => 17,
        FsError::NotEmpty(_) => 39,
        FsError::NoSpace => 28,
        FsError::Stale(_) => 116,
        _ => 5,
    };
    Response::Err { code, msg: e.to_string() }
}

impl FileServer {
    pub fn new(
        fs: FileStore,
        disk: DiskModel,
        engine: Arc<DigestEngine>,
        block_bytes: usize,
        lease_s: f64,
        metrics: Metrics,
    ) -> Self {
        FileServer {
            fs,
            disk,
            engine,
            block_bytes,
            locks: LockTable::new(lease_s),
            callbacks: Vec::new(),
            applied: HashMap::new(),
            failed: HashMap::new(),
            digest_cache: HashMap::new(),
            channel_map: HashMap::new(),
            metrics,
            up: true,
        }
    }

    /// Direct (trusted) access to the home space — used by tests, the
    /// workload generators that pre-populate the home space, and by
    /// "local edits" that simulate the user touching files at home.
    pub fn home_mut(&mut self) -> &mut FileStore {
        &mut self.fs
    }

    pub fn home(&self) -> &FileStore {
        &self.fs
    }

    pub fn is_up(&self) -> bool {
        self.up
    }

    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    /// Crash the server process: callback registrations and the in-memory
    /// lock table die with it; the home space (on disk) survives — and so
    /// does the per-client idempotence watermark (`applied`/`failed`),
    /// which the server journals to the home disk alongside the data it
    /// guards. A crashed-and-restarted server must answer replayed ops
    /// as duplicates, not re-apply them: re-application would double-bump
    /// versions and mistake a client's own earlier write for a
    /// conflicting third-party edit (DESIGN.md §2.5).
    pub fn crash(&mut self) {
        self.up = false;
        for reg in &self.callbacks {
            reg.channel.disconnect();
        }
        self.callbacks.clear();
        self.locks = LockTable::new(self.locks.lease_secs());
    }

    /// Restart (the paper uses a crontab job). Clients must re-register
    /// callbacks and re-acquire locks.
    pub fn restart(&mut self) {
        self.up = true;
    }

    /// A change made *at the home space directly* (the user editing a file
    /// on their workstation). Bumps the store and fans out invalidations
    /// to every registered client.
    pub fn local_write(&mut self, path: &str, data: &[u8], now: VirtualTime) -> Result<(), FsError> {
        self.fs.write(path, data, now)?;
        self.digest_cache.remove(&vpath::normalize(path));
        let version = self.fs.stat(path).map(|a| a.version).unwrap_or(0);
        self.notify_change(path, version, None);
        Ok(())
    }

    pub fn local_unlink(&mut self, path: &str, now: VirtualTime) -> Result<(), FsError> {
        self.fs.unlink(path, now)?;
        self.digest_cache.remove(&vpath::normalize(path));
        self.notify_removed(path, None);
        Ok(())
    }

    fn notify_change(&mut self, path: &str, new_version: u64, originator: Option<u64>) {
        let p = vpath::normalize(path);
        for reg in &self.callbacks {
            if Some(reg.client_id) == originator {
                continue;
            }
            if vpath::is_under(&p, &reg.root) && reg.channel.push(NotifyEvent::Invalidate {
                path: p.clone(),
                new_version,
            }) {
                self.metrics.incr(names::CALLBACKS_SENT);
            }
        }
    }

    fn notify_removed(&mut self, path: &str, originator: Option<u64>) {
        let p = vpath::normalize(path);
        for reg in &self.callbacks {
            if Some(reg.client_id) == originator {
                continue;
            }
            if vpath::is_under(&p, &reg.root)
                && reg.channel.push(NotifyEvent::Removed { path: p.clone() })
            {
                self.metrics.incr(names::CALLBACKS_SENT);
            }
        }
    }

    /// Expire orphaned lock leases (invoked by the coordinator's
    /// housekeeping tick and before conflicting acquires).
    pub fn expire_leases(&mut self, now: VirtualTime) -> usize {
        let n = self.locks.expire(now);
        if n > 0 {
            self.metrics.add(names::LEASE_EXPIRED, n as u64);
        }
        n
    }

    fn digests_for(&mut self, path: &str, version: u64) -> Vec<i32> {
        let key = vpath::normalize(path);
        if let Some((v, d)) = self.digest_cache.get(&key) {
            if *v == version {
                return d.clone();
            }
        }
        let data = self.fs.read(&key).map(|d| d.to_vec()).unwrap_or_default();
        let digests = self.engine.digests(&data, self.block_bytes);
        self.digest_cache.insert(key, (version, digests.clone()));
        digests
    }

    /// Handle one authenticated request from `client_id`.
    pub fn handle(&mut self, client_id: u64, req: Request, now: VirtualTime) -> Response {
        if !self.up {
            return Response::Err { code: 111, msg: "connection refused (server down)".into() };
        }
        match req {
            Request::AuthHello { .. } | Request::AuthProof { .. } => Response::Err {
                code: 1,
                msg: "auth is handled by the transport handshake".into(),
            },
            Request::Ping => Response::Pong,
            Request::Stat { path } => match self.fs.stat(&path) {
                Ok(a) => Response::Attr { attr: WireAttr::from_attr(&a) },
                Err(e) => err_resp(&e),
            },
            Request::ReadDir { path } => match self.fs.readdir(&path) {
                Ok(entries) => Response::Dir {
                    entries: entries
                        .into_iter()
                        .map(|(name, a)| DirEntry { name, attr: WireAttr::from_attr(&a) })
                        .collect(),
                },
                Err(e) => err_resp(&e),
            },
            Request::Fetch { path } => match self.fs.stat(&path) {
                Ok(a) => {
                    let digests = self.digests_for(&path, a.version);
                    let data = self.fs.read(&path).map(|d| d.to_vec()).unwrap_or_default();
                    Response::File {
                        image: FileImage {
                            path: vpath::normalize(&path),
                            version: a.version,
                            data,
                            digests,
                        },
                    }
                }
                Err(e) => err_resp(&e),
            },
            Request::FetchMeta { path } => match self.fs.stat(&path) {
                Ok(a) => {
                    let digests = self.digests_for(&path, a.version);
                    Response::FileMeta { version: a.version, size: a.size, digests }
                }
                Err(e) => err_resp(&e),
            },
            Request::FetchRange { path, offset, len, expect_version } => {
                match self.fs.stat(&path) {
                    Ok(a) if a.version != expect_version => err_resp(&FsError::Stale(format!(
                        "{path} changed during striped fetch (v{} != v{expect_version})",
                        a.version
                    ))),
                    Ok(a) => {
                        // serve whole blocks covering the range, each with
                        // its digest from the digest cache, so the client
                        // can verify and install blocks independently
                        let bb = self.block_bytes.max(1) as u64;
                        let digests = self.digests_for(&path, a.version);
                        let total = a.size.div_ceil(bb);
                        let first = (offset / bb).min(total);
                        let last = offset.saturating_add(len).min(a.size).div_ceil(bb);
                        let mut extents = Vec::with_capacity(last.saturating_sub(first) as usize);
                        let mut failed = None;
                        for b in first..last {
                            let boff = b * bb;
                            let blen = bb.min(a.size - boff) as usize;
                            match self.fs.read_at(&path, boff, blen) {
                                Ok(data) => extents.push(BlockExtent {
                                    index: b as u32,
                                    data: data.to_vec(),
                                    digest: digests.get(b as usize).copied().unwrap_or(0),
                                }),
                                Err(e) => {
                                    failed = Some(e);
                                    break;
                                }
                            }
                        }
                        match failed {
                            Some(e) => err_resp(&e),
                            None => Response::FileBlocks { version: a.version, extents },
                        }
                    }
                    Err(e) => err_resp(&e),
                }
            }
            Request::RegisterCallback { root, client_id: cid } => {
                // replace any prior registration for this client+root
                self.callbacks.retain(|r| !(r.client_id == cid && r.root == root));
                let channel = self.channel_for(cid).unwrap_or_default();
                self.callbacks.push(CallbackReg {
                    client_id: cid,
                    root: vpath::normalize(&root),
                    channel,
                });
                Response::CallbackRegistered
            }
            Request::Apply { seq, op } => self.apply(client_id, seq, op, now),
            Request::Compound { ops } => {
                // one WAN round trip, N ops: each op gets the exact
                // Response its single-op request would have produced, so
                // the client sees partial failure per op and replays only
                // what did not land (idempotent via per-client seqs).
                // (Round-trip accounting lives client-side in the links —
                // the sim deployment shares one metrics sink.)
                let replies = ops
                    .into_iter()
                    .map(|op| match op {
                        CompoundOp::Apply { seq, op } => self.apply(client_id, seq, op, now),
                        CompoundOp::Stat { path } => match self.fs.stat(&path) {
                            Ok(a) => Response::Attr { attr: WireAttr::from_attr(&a) },
                            Err(e) => err_resp(&e),
                        },
                    })
                    .collect();
                Response::CompoundReply { replies }
            }
            Request::LockAcquire { path, kind, owner } => {
                self.expire_leases(now);
                match self.locks.acquire(&vpath::normalize(&path), kind, owner, now) {
                    Acquire::Granted { token, lease } => Response::LockGranted {
                        token,
                        lease_ns: lease.saturating_sub(now).0,
                    },
                    Acquire::Denied { holder } => Response::LockDenied { holder },
                }
            }
            Request::LockRenew { token, owner } => match self.locks.renew(token, owner, now) {
                Some(expires) => {
                    self.metrics.incr(names::LEASE_RENEWALS);
                    Response::LockGranted { token, lease_ns: expires.saturating_sub(now).0 }
                }
                None => Response::Err { code: 77, msg: "lease lost".into() },
            },
            Request::LockRelease { token, owner } => {
                if self.locks.release(token, owner) {
                    Response::Released
                } else {
                    Response::Err { code: 77, msg: "no such lock".into() }
                }
            }
        }
    }

    /// Attach (or create) the callback channel for a client. The transport
    /// owns the other end.
    pub fn attach_channel(&mut self, client_id: u64, channel: NotifyChannel) {
        for reg in &mut self.callbacks {
            if reg.client_id == client_id {
                reg.channel = channel.clone();
            }
        }
        // keep a registration-less attachment so RegisterCallback can find it
        self.channel_map.insert(client_id, channel);
    }

    fn channel_for(&self, client_id: u64) -> Option<NotifyChannel> {
        self.channel_map.get(&client_id).cloned()
    }

    /// Retained failed-seq records per client (tiny; evicting the oldest
    /// only risks falsely acking a replay of a very stale failed op).
    const MAX_FAILED_SEQS: usize = 1024;

    fn apply(&mut self, client_id: u64, seq: u64, op: MetaOp, now: VirtualTime) -> Response {
        let last = self.applied.get(&client_id).copied().unwrap_or(0);
        let previously_failed =
            self.failed.get(&client_id).map(|s| s.contains(&seq)).unwrap_or(false);
        if seq <= last && !previously_failed {
            // replayed duplicate: already applied — answer success again
            let version = self.fs.stat(op.path()).map(|a| a.version).unwrap_or(0);
            return Response::Applied { seq, new_version: version };
        }
        let result: Result<Vec<(String, bool)>, FsError> = match &op {
            MetaOp::Mkdir { path } => self.fs.mkdir_p(path, now).map(|_| vec![(path.clone(), false)]),
            MetaOp::Rmdir { path } => self.fs.rmdir(path, now).map(|_| vec![(path.clone(), true)]),
            MetaOp::Create { path } => {
                let r = match self.fs.create(path, now) {
                    Ok(_) => Ok(()),
                    Err(FsError::Exists(_)) => Ok(()), // create is idempotent
                    Err(e) => Err(e),
                };
                r.map(|_| vec![(path.clone(), false)])
            }
            MetaOp::Unlink { path } => self.fs.unlink(path, now).map(|_| vec![(path.clone(), true)]),
            MetaOp::Rename { from, to } => self
                .fs
                .rename(from, to, now)
                .map(|_| vec![(from.clone(), true), (to.clone(), false)]),
            MetaOp::Truncate { path, size } => {
                self.fs.truncate(path, *size, now).map(|_| vec![(path.clone(), false)])
            }
            MetaOp::SetMode { path, mode } => {
                self.fs.set_mode(path, *mode, now).map(|_| vec![(path.clone(), false)])
            }
            MetaOp::WriteFull { path, data, digests, base_version } => {
                let mut touched = vec![(path.clone(), false)];
                if *base_version > 0 && !digests.is_empty() {
                    if let Ok(attr) = self.fs.stat(path) {
                        if attr.version != *base_version
                            && self.digests_for(path, attr.version) != *digests
                        {
                            // a disconnected-time write raced a home-side
                            // edit the client never saw: last close wins,
                            // but the losing copy is preserved beside the
                            // file instead of silently dropped (§2.5).
                            // Digest-equal content is not a conflict —
                            // nothing would be lost. The loser is COPIED
                            // aside (not renamed): the original inode must
                            // keep its version so the write below bumps it
                            // monotonically — a recreated inode would
                            // restart at a low version and other clients'
                            // `version < new_version` invalidation gate
                            // would dismiss the callback and serve stale.
                            // client_id keeps names from colliding when
                            // two clients' independent per-client seqs
                            // conflict on the same path
                            let conflict = format!(
                                "{}.xufs-conflict-{client_id}-{seq}",
                                vpath::normalize(path)
                            );
                            let loser = self.fs.read(path).map(|d| d.to_vec());
                            if let Ok(loser) = loser {
                                if self.fs.write(&conflict, &loser, now).is_ok() {
                                    self.metrics.incr(names::CONFLICT_FILES);
                                    touched.push((conflict, false));
                                }
                            }
                        }
                    }
                }
                let r = self.fs.write(path, data, now);
                if r.is_ok() && !digests.is_empty() {
                    let v = self.fs.stat(path).map(|a| a.version).unwrap_or(0);
                    self.digest_cache.insert(vpath::normalize(path), (v, digests.clone()));
                }
                r.map(|_| touched)
            }
            MetaOp::WriteDelta { path, total_size, base_version, blocks, digests } => {
                self.apply_delta(path, *total_size, *base_version, blocks, digests, now)
                    .map(|_| vec![(path.clone(), false)])
            }
        };
        match result {
            Ok(touched) => {
                // max(): a successful retry of a previously-failed low seq
                // must not regress the watermark
                let wm = self.applied.entry(client_id).or_insert(0);
                *wm = (*wm).max(seq);
                if previously_failed {
                    if let Some(s) = self.failed.get_mut(&client_id) {
                        s.remove(&seq);
                    }
                }
                let version = self.fs.stat(op.path()).map(|a| a.version).unwrap_or(0);
                for (path, removed) in touched {
                    if removed {
                        self.digest_cache.remove(&vpath::normalize(&path));
                        self.notify_removed(&path, Some(client_id));
                    } else {
                        let v = self.fs.stat(&path).map(|a| a.version).unwrap_or(version);
                        self.notify_change(&path, v, Some(client_id));
                    }
                }
                Response::Applied { seq, new_version: version }
            }
            Err(e) => {
                let set = self.failed.entry(client_id).or_default();
                set.insert(seq);
                while set.len() > Self::MAX_FAILED_SEQS {
                    set.pop_first();
                }
                err_resp(&e)
            }
        }
    }

    /// Apply a delta writeback: only valid against the exact base version
    /// the client diffed from; otherwise the client must fall back to a
    /// full write (the server's copy changed concurrently).
    fn apply_delta(
        &mut self,
        path: &str,
        total_size: u64,
        base_version: u64,
        blocks: &[(u32, Vec<u8>)],
        digests: &[i32],
        now: VirtualTime,
    ) -> Result<(), FsError> {
        let attr = self.fs.stat(path)?;
        if attr.version != base_version {
            return Err(FsError::Stale(format!(
                "delta base version {base_version} != server version {}",
                attr.version
            )));
        }
        let mut data = self.fs.read(path)?.to_vec();
        data.resize(total_size as usize, 0);
        for (idx, payload) in blocks {
            let start = *idx as usize * self.block_bytes;
            let end = (start + payload.len()).min(data.len());
            if start > data.len() {
                return Err(FsError::Invalid(format!("delta block {idx} beyond file size")));
            }
            data[start..end].copy_from_slice(&payload[..end - start]);
        }
        self.fs.write(path, &data, now)?;
        if !digests.is_empty() {
            let v = self.fs.stat(path).map(|a| a.version).unwrap_or(0);
            self.digest_cache.insert(vpath::normalize(path), (v, digests.to_vec()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::LockKind;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs(s)
    }

    fn server() -> FileServer {
        let mut fs = FileStore::default();
        fs.mkdir_p("/home/user", t(0.0)).unwrap();
        fs.write("/home/user/a.txt", b"hello world", t(0.0)).unwrap();
        fs.write("/home/user/b.dat", &[7u8; 200_000], t(0.0)).unwrap();
        FileServer::new(
            fs,
            DiskModel::new(200.0e6, 0.002),
            Arc::new(DigestEngine::native(Metrics::new())),
            65536,
            30.0,
            Metrics::new(),
        )
    }

    #[test]
    fn stat_and_readdir() {
        let mut s = server();
        match s.handle(1, Request::Stat { path: "/home/user/a.txt".into() }, t(1.0)) {
            Response::Attr { attr } => assert_eq!(attr.size, 11),
            r => panic!("{r:?}"),
        }
        match s.handle(1, Request::ReadDir { path: "/home/user".into() }, t(1.0)) {
            Response::Dir { entries } => {
                assert_eq!(entries.len(), 2);
                assert_eq!(entries[0].name, "a.txt");
            }
            r => panic!("{r:?}"),
        }
        match s.handle(1, Request::Stat { path: "/missing".into() }, t(1.0)) {
            Response::Err { code, .. } => assert_eq!(code, 2),
            r => panic!("{r:?}"),
        }
    }

    #[test]
    fn fetch_includes_verifiable_digests() {
        let mut s = server();
        match s.handle(1, Request::Fetch { path: "/home/user/b.dat".into() }, t(1.0)) {
            Response::File { image } => {
                assert_eq!(image.data.len(), 200_000);
                assert_eq!(image.digests.len(), 4); // ceil(200000/65536)
                let engine = DigestEngine::native(Metrics::new());
                assert_eq!(engine.digests(&image.data, 65536), image.digests);
            }
            r => panic!("{r:?}"),
        }
    }

    #[test]
    fn digest_cache_reused_until_version_changes() {
        let mut s = server();
        s.handle(1, Request::Fetch { path: "/home/user/a.txt".into() }, t(1.0));
        let m = Metrics::new();
        let e = Arc::new(DigestEngine::native(m.clone()));
        s.engine = e;
        // same version: cache hit, engine not consulted
        s.handle(1, Request::Fetch { path: "/home/user/a.txt".into() }, t(2.0));
        assert_eq!(m.counter(names::DIGEST_CALLS), 0);
        s.local_write("/home/user/a.txt", b"changed", t(3.0)).unwrap();
        s.handle(1, Request::Fetch { path: "/home/user/a.txt".into() }, t(4.0));
        assert_eq!(m.counter(names::DIGEST_CALLS), 1);
    }

    #[test]
    fn fetch_range_serves_block_extents_with_digests() {
        let mut s = server();
        // whole-file digests (fills the digest cache)
        let whole = match s.handle(1, Request::Fetch { path: "/home/u/b.dat".into() }, t(1.0)) {
            Response::File { image } => image,
            r => panic!("{r:?}"),
        };
        let v = s.home().stat("/home/u/b.dat").unwrap().version;
        // a mid-file byte range comes back as the covering blocks, each
        // carrying the digest the whole-file fetch reported
        let r = s.handle(
            1,
            Request::FetchRange {
                path: "/home/u/b.dat".into(),
                offset: 65536 + 10,
                len: 65536,
                expect_version: v,
            },
            t(2.0),
        );
        let Response::FileBlocks { version, extents } = r else { panic!("{r:?}") };
        assert_eq!(version, v);
        assert_eq!(extents.len(), 2); // blocks 1 and 2 cover the range
        assert_eq!(extents[0].index, 1);
        assert_eq!(extents[1].index, 2);
        for x in &extents {
            let start = x.index as usize * 65536;
            assert_eq!(x.data, whole.data[start..start + x.data.len()]);
            assert_eq!(x.digest, whole.digests[x.index as usize]);
        }
        // the tail block is short, clamped to the file size
        let r = s.handle(
            1,
            Request::FetchRange {
                path: "/home/u/b.dat".into(),
                offset: 199_000,
                len: 1 << 20,
                expect_version: v,
            },
            t(3.0),
        );
        let Response::FileBlocks { extents, .. } = r else { panic!("{r:?}") };
        assert_eq!(extents.len(), 1);
        assert_eq!(extents[0].index, 3);
        assert_eq!(extents[0].data.len(), 200_000 - 3 * 65536);
        // out-of-range offsets yield an empty (not erroneous) reply
        let r = s.handle(
            1,
            Request::FetchRange {
                path: "/home/u/b.dat".into(),
                offset: 10 << 20,
                len: 4096,
                expect_version: v,
            },
            t(4.0),
        );
        assert!(matches!(r, Response::FileBlocks { ref extents, .. } if extents.is_empty()), "{r:?}");
    }

    #[test]
    fn apply_is_idempotent_per_client() {
        let mut s = server();
        let op = MetaOp::WriteFull {
            path: "/home/user/new".into(),
            data: b"v1".to_vec(),
            digests: vec![],
            base_version: 0,
        };
        let r1 = s.handle(1, Request::Apply { seq: 1, op: op.clone() }, t(1.0));
        assert!(matches!(r1, Response::Applied { seq: 1, .. }));
        let v1 = s.home().stat("/home/user/new").unwrap().version;
        // replay of the same seq must not bump the version
        let r2 = s.handle(1, Request::Apply { seq: 1, op }, t(2.0));
        assert!(matches!(r2, Response::Applied { seq: 1, .. }));
        assert_eq!(s.home().stat("/home/user/new").unwrap().version, v1);
    }

    #[test]
    fn compound_applies_in_order_with_per_op_status() {
        let mut s = server();
        let r = s.handle(
            1,
            Request::Compound {
                ops: vec![
                    CompoundOp::Apply { seq: 1, op: MetaOp::Mkdir { path: "/home/user/new".into() } },
                    CompoundOp::Apply {
                        seq: 2,
                        op: MetaOp::WriteFull {
                            path: "/home/user/new/f.txt".into(),
                            data: b"compound".to_vec(),
                            digests: vec![],
                            base_version: 0,
                        },
                    },
                    // semantic failure mid-batch must not stop later ops
                    CompoundOp::Apply { seq: 3, op: MetaOp::Unlink { path: "/home/user/ghost".into() } },
                    CompoundOp::Stat { path: "/home/user/new/f.txt".into() },
                ],
            },
            t(1.0),
        );
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert_eq!(replies.len(), 4);
        assert!(matches!(replies[0], Response::Applied { seq: 1, .. }));
        assert!(matches!(replies[1], Response::Applied { seq: 2, .. }));
        assert!(matches!(replies[2], Response::Err { code: 2, .. }));
        assert!(matches!(&replies[3], Response::Attr { attr } if attr.size == 8));
        assert_eq!(s.home().read("/home/user/new/f.txt").unwrap(), b"compound");
        // a failed op does not advance the idempotence watermark past it:
        // replaying seq 3 after fixing the cause still applies
        s.home_mut().write("/home/user/ghost", b"x", t(2.0)).unwrap();
        let r = s.handle(
            1,
            Request::Compound {
                ops: vec![CompoundOp::Apply { seq: 3, op: MetaOp::Unlink { path: "/home/user/ghost".into() } }],
            },
            t(3.0),
        );
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert!(matches!(replies[0], Response::Applied { seq: 3, .. }), "{replies:?}");
        assert!(!s.home().exists("/home/user/ghost"));
    }

    #[test]
    fn compound_replay_retries_failed_ops_not_false_acks() {
        let mut s = server();
        let ops = vec![
            // fails (no such file) while the NEXT op advances the watermark
            CompoundOp::Apply { seq: 1, op: MetaOp::Unlink { path: "/home/user/ghost".into() } },
            CompoundOp::Apply { seq: 2, op: MetaOp::Mkdir { path: "/home/user/d2".into() } },
        ];
        let r = s.handle(1, Request::Compound { ops: ops.clone() }, t(1.0));
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert!(matches!(replies[0], Response::Err { code: 2, .. }));
        assert!(matches!(replies[1], Response::Applied { seq: 2, .. }));
        // the reply frame is lost; the client replays the whole compound.
        // The failed seq must fail AGAIN — answering it as a duplicate
        // would falsely ack a write that never landed.
        let r = s.handle(1, Request::Compound { ops }, t(2.0));
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert!(matches!(replies[0], Response::Err { code: 2, .. }), "{replies:?}");
        assert!(matches!(replies[1], Response::Applied { seq: 2, .. }));
        // once the cause is fixed, a retry under the SAME seq applies...
        s.home_mut().write("/home/user/ghost", b"x", t(3.0)).unwrap();
        let r = s.handle(
            1,
            Request::Apply { seq: 1, op: MetaOp::Unlink { path: "/home/user/ghost".into() } },
            t(4.0),
        );
        assert!(matches!(r, Response::Applied { seq: 1, .. }), "{r:?}");
        assert!(!s.home().exists("/home/user/ghost"));
        // ...and the watermark did not regress: seq 2 is still a duplicate
        let before = s.home().stat("/home/user/d2").unwrap().version;
        let r = s.handle(
            1,
            Request::Apply { seq: 2, op: MetaOp::Mkdir { path: "/home/user/d2".into() } },
            t(5.0),
        );
        assert!(matches!(r, Response::Applied { seq: 2, .. }));
        assert_eq!(s.home().stat("/home/user/d2").unwrap().version, before);
    }

    #[test]
    fn compound_replay_is_idempotent() {
        let mut s = server();
        let ops = vec![
            CompoundOp::Apply {
                seq: 1,
                op: MetaOp::WriteFull {
                    path: "/home/user/q".into(),
                    data: b"v".to_vec(),
                    digests: vec![],
                    base_version: 0,
                },
            },
            CompoundOp::Apply { seq: 2, op: MetaOp::Mkdir { path: "/home/user/d".into() } },
        ];
        s.handle(1, Request::Compound { ops: ops.clone() }, t(1.0));
        let v1 = s.home().stat("/home/user/q").unwrap().version;
        // whole-compound replay after a lost reply: versions must not move
        let r = s.handle(1, Request::Compound { ops }, t(2.0));
        let Response::CompoundReply { replies } = r else { panic!("{r:?}") };
        assert!(replies.iter().all(|r| matches!(r, Response::Applied { .. })));
        assert_eq!(s.home().stat("/home/user/q").unwrap().version, v1);
    }

    #[test]
    fn apply_notifies_other_clients_not_originator() {
        let mut s = server();
        let ch1 = NotifyChannel::new();
        let ch2 = NotifyChannel::new();
        s.attach_channel(1, ch1.clone());
        s.attach_channel(2, ch2.clone());
        s.handle(1, Request::RegisterCallback { root: "/home/user".into(), client_id: 1 }, t(0.0));
        s.handle(2, Request::RegisterCallback { root: "/home/user".into(), client_id: 2 }, t(0.0));
        let op = MetaOp::WriteFull {
            path: "/home/user/a.txt".into(),
            data: b"x".to_vec(),
            digests: vec![],
            base_version: 0,
        };
        s.handle(1, Request::Apply { seq: 1, op }, t(1.0));
        assert_eq!(ch1.pending(), 0, "originator must not be invalidated");
        let evs = ch2.drain();
        assert_eq!(evs.len(), 1);
        assert!(matches!(&evs[0], NotifyEvent::Invalidate { path, .. } if path == "/home/user/a.txt"));
    }

    #[test]
    fn local_write_invalidates_everyone() {
        let mut s = server();
        let ch = NotifyChannel::new();
        s.attach_channel(1, ch.clone());
        s.handle(1, Request::RegisterCallback { root: "/home/user".into(), client_id: 1 }, t(0.0));
        s.local_write("/home/user/a.txt", b"edited at home", t(1.0)).unwrap();
        assert_eq!(ch.pending(), 1);
        s.local_unlink("/home/user/a.txt", t(2.0)).unwrap();
        let evs = ch.drain();
        assert!(matches!(&evs[1], NotifyEvent::Removed { path } if path == "/home/user/a.txt"));
    }

    #[test]
    fn delta_against_stale_base_rejected() {
        let mut s = server();
        let base = s.home().stat("/home/user/b.dat").unwrap().version;
        s.local_write("/home/user/b.dat", &[9u8; 100], t(1.0)).unwrap();
        let r = s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteDelta {
                    path: "/home/user/b.dat".into(),
                    total_size: 100,
                    base_version: base,
                    blocks: vec![(0, vec![1; 64])],
                    digests: vec![],
                },
            },
            t(2.0),
        );
        assert!(matches!(r, Response::Err { code: 116, .. }), "{r:?}");
    }

    #[test]
    fn delta_applies_blocks() {
        let mut s = server();
        let base = s.home().stat("/home/user/b.dat").unwrap().version;
        let mut expect = s.home().read("/home/user/b.dat").unwrap().to_vec();
        let blk = vec![0xABu8; 65536];
        expect[65536..131072].copy_from_slice(&blk);
        let r = s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::WriteDelta {
                    path: "/home/user/b.dat".into(),
                    total_size: 200_000,
                    base_version: base,
                    blocks: vec![(1, blk)],
                    digests: vec![],
                },
            },
            t(2.0),
        );
        assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        assert_eq!(s.home().read("/home/user/b.dat").unwrap(), &expect[..]);
    }

    #[test]
    fn crash_refuses_and_restart_recovers() {
        let mut s = server();
        let ch = NotifyChannel::new();
        s.attach_channel(1, ch.clone());
        s.handle(1, Request::RegisterCallback { root: "/".into(), client_id: 1 }, t(0.0));
        s.handle(1, Request::LockAcquire { path: "/home/user/a.txt".into(), kind: LockKind::Exclusive, owner: 1 }, t(0.0));
        s.crash();
        assert!(!ch.is_connected());
        assert!(matches!(s.handle(1, Request::Ping, t(1.0)), Response::Err { code: 111, .. }));
        s.restart();
        assert!(matches!(s.handle(1, Request::Ping, t(2.0)), Response::Pong));
        // lock table was lost in the crash: a new owner can acquire
        let r = s.handle(
            2,
            Request::LockAcquire { path: "/home/user/a.txt".into(), kind: LockKind::Exclusive, owner: 2 },
            t(3.0),
        );
        assert!(matches!(r, Response::LockGranted { .. }));
    }

    #[test]
    fn lock_lifecycle_over_protocol() {
        let mut s = server();
        let r = s.handle(
            1,
            Request::LockAcquire { path: "/f".into(), kind: LockKind::Exclusive, owner: 10 },
            t(0.0),
        );
        let Response::LockGranted { token, lease_ns } = r else { panic!("{r:?}") };
        assert_eq!(lease_ns, 30_000_000_000);
        assert!(matches!(
            s.handle(2, Request::LockAcquire { path: "/f".into(), kind: LockKind::Shared, owner: 11 }, t(1.0)),
            Response::LockDenied { holder: 10 }
        ));
        assert!(matches!(
            s.handle(1, Request::LockRenew { token, owner: 10 }, t(10.0)),
            Response::LockGranted { .. }
        ));
        assert!(matches!(s.handle(1, Request::LockRelease { token, owner: 10 }, t(11.0)), Response::Released));
        assert!(matches!(
            s.handle(2, Request::LockAcquire { path: "/f".into(), kind: LockKind::Shared, owner: 11 }, t(12.0)),
            Response::LockGranted { .. }
        ));
    }

    #[test]
    fn rename_notifies_both_paths() {
        let mut s = server();
        let ch = NotifyChannel::new();
        s.attach_channel(2, ch.clone());
        s.handle(2, Request::RegisterCallback { root: "/home/user".into(), client_id: 2 }, t(0.0));
        s.handle(
            1,
            Request::Apply {
                seq: 1,
                op: MetaOp::Rename { from: "/home/user/a.txt".into(), to: "/home/user/c.txt".into() },
            },
            t(1.0),
        );
        let evs = ch.drain();
        assert_eq!(evs.len(), 2);
        assert!(matches!(&evs[0], NotifyEvent::Removed { path } if path == "/home/user/a.txt"));
        assert!(matches!(&evs[1], NotifyEvent::Invalidate { path, .. } if path == "/home/user/c.txt"));
    }
}
