//! Home-server replication (DESIGN.md §2.7).
//!
//! XUFS's single home server is the last single point of failure the
//! paper leaves standing: clients survive disconnection and WAN
//! partitions, but a crashed home node stalls every private namespace it
//! exports until crontab restarts it. This module adds the warm standby:
//! the primary [`FileServer`](crate::server::FileServer) records every
//! *genuine* application outcome in an applied-op log (successful client
//! ops with their resulting version, semantic failures, home-side local
//! edits — [`ReplRecord`]), and a [`Shipper`] streams that log, HMAC-
//! framed exactly like the PR 3 durable op-log records, to a secondary
//! `FileServer` over any [`ServerLink`].
//!
//! The secondary ingests records in strict `ship_seq` order through its
//! normal apply path, so everything the consistency protocol depends on
//! replicates *by construction*:
//!
//! * per-(client, seq) idempotence watermarks and failed-seq sets — a
//!   post-failover replay of an op the primary already acknowledged is
//!   answered as a duplicate, never re-applied;
//! * conflict preservation — a replayed `WriteFull { base_version }`
//!   re-runs the same digest comparison against the same store state,
//!   so `.xufs-conflict-*` files appear exactly once;
//! * version monotonicity — the secondary's inodes take exactly the
//!   version bumps the primary's did, in the same order.
//!
//! **Durability model.** The applied-op log lives on the primary's home
//! disk next to the namespace it guards (the paper's server is a user
//! process restarted by crontab: a crash kills the process, not the
//! disk). The shipper is a sidecar on the same host, so it keeps
//! draining the durable log even while the server process is down —
//! which is what lets an explicit [`Request::Promote`] first catch the
//! secondary up to the log's end and then switch roles without losing
//! acknowledged operations. A full *host* loss would forfeit the
//! unshipped tail (bounded by `replica.max_lag_ops`); fencing that
//! requires synchronous shipping, which the paper's WAN budget rules
//! out (DESIGN.md §2.7 discusses the trade).
//!
//! **Replication by reference (DESIGN.md §2.8).** On a chunked home
//! store the log spills write payloads as [`crate::proto::MetaOp::WriteRef`]
//! digest lists instead of bytes. A secondary missing some of a batch's
//! chunk payloads answers `ReplicaNeed` (nothing applies); the shipper
//! reads exactly those chunks off the primary's store and pushes them
//! (`Request::ChunkPush`), then re-sends the same batch — dedup means a
//! chunk crosses the WAN at most once, however many files or log
//! records reference it. Prefixes the secondary has acked are truncated
//! from the primary's log (`FileServer::repl_truncate_acked`), so the
//! log's unbounded-growth caveat from PR 5 is gone.
//!
//! Wire framing: each record travels as
//! `len:u32le | record-bytes | hmac:[u8;32]` with
//! `hmac = HMAC-SHA256("xufs-repl-v1", record-bytes)` — a torn or
//! tampered frame fails verification and the whole batch is refused
//! (the shipper simply re-sends; ingestion is idempotent).

use crate::client::ServerLink;
use crate::homefs::FsError;
use crate::metrics::{names, Metrics};
use crate::proto::{ProtoError, ReplRecord, Request, Response};
use crate::server::FileServer;
use crate::util::hmacsha;

/// HMAC key for replication frames (versioned like the op-log key).
const REPL_HMAC_KEY: &[u8] = b"xufs-repl-v1";
const FRAME_HDR: usize = 4;
const FRAME_MAC: usize = 32;

/// Encode records as a contiguous run of HMAC frames (the payload of one
/// [`Request::Replicate`]).
pub fn frame_records(records: &[ReplRecord]) -> Vec<u8> {
    let mut out = Vec::new();
    for rec in records {
        let body = rec.encode();
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out.extend_from_slice(&hmacsha::hmac_sha256(REPL_HMAC_KEY, &[&body]));
    }
    out
}

/// Decode and verify a run of HMAC frames. Any torn, short, or tampered
/// frame fails the WHOLE batch — the shipper re-sends and the secondary's
/// gapless-ingest rule makes the retry safe.
pub fn decode_frames(buf: &[u8]) -> Result<Vec<ReplRecord>, ProtoError> {
    let mut out = Vec::new();
    let mut at = 0usize;
    while at < buf.len() {
        if buf.len() - at < FRAME_HDR + FRAME_MAC {
            return Err(ProtoError("torn replication frame header".into()));
        }
        let mut len_bytes = [0u8; 4];
        len_bytes.copy_from_slice(&buf[at..at + 4]);
        let len = u32::from_le_bytes(len_bytes) as usize;
        let Some(end) = at
            .checked_add(FRAME_HDR)
            .and_then(|x| x.checked_add(len))
            .and_then(|x| x.checked_add(FRAME_MAC))
        else {
            return Err(ProtoError("replication frame length overflow".into()));
        };
        if end > buf.len() {
            return Err(ProtoError("torn replication frame payload".into()));
        }
        let body = &buf[at + FRAME_HDR..at + FRAME_HDR + len];
        let mac = &buf[at + FRAME_HDR + len..end];
        let want = hmacsha::hmac_sha256(REPL_HMAC_KEY, &[body]);
        if !hmacsha::ct_eq(mac, &want) {
            return Err(ProtoError("replication frame failed HMAC verification".into()));
        }
        out.push(ReplRecord::decode(body)?);
        at = end;
    }
    Ok(out)
}

/// The log-shipping sidecar: reads the primary's durable applied-op log
/// locally (same host — no WAN) and streams it to the secondary over a
/// [`ServerLink`] in bounded batches. One WAN round trip per batch; the
/// ack carries the secondary's new watermark, which is the only cursor
/// state the shipper trusts (a lost ack just re-ships, idempotently).
pub struct Shipper<L: ServerLink> {
    link: L,
    /// Records per `Replicate` frame (`replica.ship_batch`).
    batch: usize,
    /// The secondary's global watermark as of the last ack/resync.
    cursor: u64,
}

impl<L: ServerLink> Shipper<L> {
    pub fn new(link: L, batch: usize) -> Self {
        Shipper { link, batch: batch.max(1), cursor: 0 }
    }

    pub fn link(&self) -> &L {
        &self.link
    }

    pub fn link_mut(&mut self) -> &mut L {
        &mut self.link
    }

    /// The secondary's watermark as last observed (pessimistic: a lost
    /// ack under-reports, which only causes an idempotent re-ship).
    pub fn watermark(&self) -> u64 {
        self.cursor
    }

    /// How many applied ops the secondary is behind the primary's log.
    pub fn lag(&self, primary: &FileServer) -> u64 {
        primary.repl_ship_seq().saturating_sub(self.cursor)
    }

    /// Re-read the secondary's global watermark (after a reconnect, or
    /// when a fresh shipper attaches to a secondary with history).
    pub fn resync(&mut self) -> Result<u64, FsError> {
        match self.link.rpc(Request::WatermarkQuery { shard: u32::MAX })? {
            Response::Watermark { watermark, .. } => {
                self.cursor = self.cursor.max(watermark);
                Ok(self.cursor)
            }
            Response::Err { code: 111, .. } | Response::Err { code: 112, .. } => {
                Err(FsError::Disconnected)
            }
            r => Err(FsError::Protocol(format!("unexpected watermark reply {r:?}"))),
        }
    }

    /// Ship everything the primary's log holds beyond the secondary's
    /// watermark, in `batch`-sized frames. Returns the remaining lag
    /// (0 on full drain; an `Err` leaves the cursor where the last ack
    /// put it — the next call resumes). Also refreshes the
    /// `replica.lag_ops` gauge and counts `replica.ship_batches`.
    pub fn ship(&mut self, primary: &FileServer, metrics: &Metrics) -> Result<u64, FsError> {
        let result = self.ship_inner(primary, metrics);
        metrics.set_gauge(names::REPLICA_LAG, self.lag(primary) as f64);
        result?;
        Ok(self.lag(primary))
    }

    fn ship_inner(&mut self, primary: &FileServer, metrics: &Metrics) -> Result<(), FsError> {
        // per-drain bound on chunk-fill rounds for ONE batch: each round
        // must shrink the secondary's missing set, so hitting the bound
        // means the pushes are not sticking (divergence) — surface it
        // rather than spin on the WAN.
        let mut fill_rounds = 0u32;
        while self.cursor < primary.repl_ship_seq() {
            let records = primary.repl_records_after(self.cursor, self.batch);
            if records.is_empty() {
                return Ok(());
            }
            let from = records[0].ship_seq;
            let frames = frame_records(&records);
            // announce the primary's CURRENT log head with every batch:
            // it is how a read-serving secondary learns it has drifted
            // past the staleness bound (DESIGN.md §2.11)
            let head = primary.repl_ship_seq();
            match self.link.rpc(Request::Replicate { from, frames, head })? {
                Response::ReplicaAck { watermark } => {
                    if watermark <= self.cursor {
                        // the secondary refused to advance (gap or
                        // divergence): surface it rather than spin
                        return Err(FsError::Protocol(format!(
                            "replication stalled at watermark {watermark} (cursor {})",
                            self.cursor
                        )));
                    }
                    self.cursor = watermark;
                    fill_rounds = 0;
                    metrics.incr(names::REPLICA_SHIP_BATCHES);
                }
                Response::ReplicaNeed { digests } => {
                    // ref-based shipping (DESIGN.md §2.8): the batch
                    // names chunks the secondary lacks. Push exactly
                    // those payloads (read locally off the primary's
                    // chunk store), then loop to re-send the SAME batch
                    // — the cursor has not moved.
                    fill_rounds += 1;
                    if fill_rounds > 4 {
                        return Err(FsError::Protocol(format!(
                            "secondary still missing {} chunks after {} fill rounds",
                            digests.len(),
                            fill_rounds - 1
                        )));
                    }
                    let chunks = primary.read_chunks(&digests);
                    if chunks.len() != digests.len() {
                        // log pins make this unreachable unless the logs
                        // diverged; never ship a partial fill silently
                        return Err(FsError::Protocol(format!(
                            "primary holds {}/{} chunks the secondary needs",
                            chunks.len(),
                            digests.len()
                        )));
                    }
                    match self.link.rpc(Request::ChunkPush { chunks })? {
                        Response::ChunkAck { .. } => {
                            metrics.incr(names::REPLICA_CHUNK_PUSHES);
                        }
                        Response::Err { code: 111, .. } | Response::Err { code: 112, .. } => {
                            return Err(FsError::Disconnected)
                        }
                        r => {
                            return Err(FsError::Protocol(format!(
                                "unexpected chunk-push reply {r:?}"
                            )))
                        }
                    }
                }
                Response::Err { code: 111, .. } | Response::Err { code: 112, .. } => {
                    return Err(FsError::Disconnected)
                }
                r => return Err(FsError::Protocol(format!("unexpected replicate reply {r:?}"))),
            }
        }
        Ok(())
    }

    /// The repair plane's fetch side (DESIGN.md §2.10): ask the peer
    /// for chunk payloads by digest. The peer only ships bytes that
    /// verify against its own copy (rotted/missing chunks are omitted),
    /// and the caller re-verifies each fill before installing it
    /// ([`FileServer::repair_chunks`]) — so a fill that rots in flight
    /// is dropped, never served. Returns however many fills arrived;
    /// fewer than asked just means the peer could not vouch for the
    /// rest (retry later or against another peer).
    pub fn fetch_chunks(&mut self, digests: &[crate::chunkstore::Digest]) -> Result<Vec<Vec<u8>>, FsError> {
        if digests.is_empty() {
            return Ok(Vec::new());
        }
        match self.link.rpc(Request::ChunkFetch { digests: digests.to_vec() })? {
            Response::ChunkFill { chunks } => Ok(chunks),
            Response::Err { code: 111, .. } | Response::Err { code: 112, .. } => {
                Err(FsError::Disconnected)
            }
            r => Err(FsError::Protocol(format!("unexpected chunk-fetch reply {r:?}"))),
        }
    }

    /// The explicit promotion step: the secondary (already caught up —
    /// call [`Self::ship`] to lag 0 first) takes over as primary.
    /// Returns the log position it took over at.
    pub fn promote(&mut self) -> Result<u64, FsError> {
        match self.link.rpc(Request::Promote)? {
            Response::Promoted { watermark } => Ok(watermark),
            Response::Err { code: 111, .. } | Response::Err { code: 112, .. } => {
                Err(FsError::Disconnected)
            }
            r => Err(FsError::Protocol(format!("unexpected promote reply {r:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::{MetaOp, ReplPayload};

    fn rec(ship_seq: u64) -> ReplRecord {
        ReplRecord {
            ship_seq,
            shard: (ship_seq % 4) as u32,
            payload: ReplPayload::Op {
                client_id: 1,
                seq: ship_seq,
                new_version: ship_seq + 1,
                op: MetaOp::WriteFull {
                    path: format!("/f{ship_seq}"),
                    data: vec![ship_seq as u8; 64],
                    digests: vec![3],
                    base_version: 0,
                },
            },
        }
    }

    #[test]
    fn frames_roundtrip() {
        let records: Vec<ReplRecord> = (1..=5).map(rec).collect();
        let buf = frame_records(&records);
        assert_eq!(decode_frames(&buf).unwrap(), records);
        assert_eq!(decode_frames(&[]).unwrap(), Vec::<ReplRecord>::new());
    }

    #[test]
    fn torn_and_tampered_frames_rejected() {
        let records: Vec<ReplRecord> = (1..=3).map(rec).collect();
        let buf = frame_records(&records);
        // a cut exactly between frames is a valid SHORTER batch (the
        // shipper's reply-loss re-send depends on that); any other
        // prefix is torn and refuses wholesale — never a panic, never a
        // partial accept
        let mut boundaries = vec![0usize];
        for r in &records {
            let len = FRAME_HDR + r.encode().len() + FRAME_MAC;
            boundaries.push(boundaries.last().unwrap() + len);
        }
        for cut in 1..buf.len() {
            match decode_frames(&buf[..cut]) {
                Ok(got) => {
                    let k = boundaries
                        .iter()
                        .position(|b| *b == cut)
                        .unwrap_or_else(|| panic!("non-boundary prefix of {cut} bytes accepted"));
                    assert_eq!(got, records[..k], "boundary cut {cut}");
                }
                Err(_) => {
                    assert!(
                        !boundaries.contains(&cut),
                        "boundary cut {cut} must decode to a record prefix"
                    );
                }
            }
        }
        // a flipped payload byte fails the HMAC
        let mut bad = buf.clone();
        bad[FRAME_HDR + 2] ^= 0xFF;
        assert!(decode_frames(&bad).is_err());
        // a flipped MAC byte likewise
        let mut bad = buf;
        let first_len = u32::from_le_bytes([bad[0], bad[1], bad[2], bad[3]]) as usize;
        bad[FRAME_HDR + first_len] ^= 0x01;
        assert!(decode_frames(&bad).is_err());
    }

    #[test]
    fn absurd_frame_length_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        buf.extend_from_slice(&[0u8; 64]);
        assert!(decode_frames(&buf).is_err());
    }
}
