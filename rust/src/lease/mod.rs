//! Lock leases (paper §3.1).
//!
//! File locking operations — except for files in *localized directories* —
//! are forwarded to the file server through the lease manager. The server
//! grants locks with a bounded lease; the client-side [`LeaseManager`]
//! renews held leases before they lapse, and the server-side [`LockTable`]
//! expires leases that stop being renewed (orphaned locks after a client
//! crash or disconnection).

use std::collections::HashMap;

use crate::proto::LockKind;
use crate::simnet::VirtualTime;

/// A granted lock on the server.
#[derive(Debug, Clone, PartialEq)]
pub struct LockRec {
    pub token: u64,
    pub path: String,
    pub kind: LockKind,
    pub owner: u64,
    pub expires: VirtualTime,
}

/// Outcome of an acquire attempt.
#[derive(Debug, Clone, PartialEq)]
pub enum Acquire {
    Granted { token: u64, lease: VirtualTime },
    Denied { holder: u64 },
}

/// Server-side lock table with lease expiry.
///
/// The sharded server (DESIGN.md §2.6) runs one table per namespace
/// shard. Conflicting acquires always land in the same table (locks
/// route by path hash), but renew/release carry only a token — so each
/// table mints tokens from a disjoint arithmetic progression
/// ([`LockTable::with_tokens`]) and the server routes a token back to
/// its shard from the token value alone.
///
/// (Deliberately no `Default`: a zero `token_step` would mint the same
/// token forever — construct via [`LockTable::new`] or
/// [`LockTable::with_tokens`].)
#[derive(Debug)]
pub struct LockTable {
    locks: HashMap<u64, LockRec>,
    next_token: u64,
    token_step: u64,
    lease_s: f64,
}

impl LockTable {
    pub fn new(lease_s: f64) -> Self {
        Self::with_tokens(lease_s, 1, 1)
    }

    /// A table whose tokens are `first_token + k * token_step` — shard
    /// `i` of `n` uses `with_tokens(lease_s, i + 1, n)`, so
    /// `(token - 1) % n` recovers the owning shard.
    pub fn with_tokens(lease_s: f64, first_token: u64, token_step: u64) -> Self {
        LockTable {
            locks: HashMap::new(),
            next_token: first_token,
            token_step: token_step.max(1),
            lease_s,
        }
    }

    pub fn lease_secs(&self) -> f64 {
        self.lease_s
    }

    fn conflicts(&self, path: &str, kind: LockKind, owner: u64, now: VirtualTime) -> Option<u64> {
        self.locks.values().find_map(|l| {
            if l.path != path || l.expires <= now || l.owner == owner {
                return None;
            }
            match (l.kind, kind) {
                (LockKind::Shared, LockKind::Shared) => None,
                _ => Some(l.owner),
            }
        })
    }

    /// Try to acquire; shared locks coexist, exclusive locks conflict with
    /// everything held by *other* owners. Expired locks never conflict.
    pub fn acquire(&mut self, path: &str, kind: LockKind, owner: u64, now: VirtualTime) -> Acquire {
        if let Some(holder) = self.conflicts(path, kind, owner, now) {
            return Acquire::Denied { holder };
        }
        let token = self.next_token;
        self.next_token += self.token_step;
        let expires = now.add_secs(self.lease_s);
        self.locks.insert(token, LockRec { token, path: path.to_string(), kind, owner, expires });
        Acquire::Granted { token, lease: expires }
    }

    /// Renew a lease (owner must match). Returns the new expiry.
    pub fn renew(&mut self, token: u64, owner: u64, now: VirtualTime) -> Option<VirtualTime> {
        let lease_s = self.lease_s;
        let l = self.locks.get_mut(&token)?;
        if l.owner != owner || l.expires <= now {
            return None;
        }
        l.expires = now.add_secs(lease_s);
        Some(l.expires)
    }

    /// Release (owner must match).
    pub fn release(&mut self, token: u64, owner: u64) -> bool {
        match self.locks.get(&token) {
            Some(l) if l.owner == owner => {
                self.locks.remove(&token);
                true
            }
            _ => false,
        }
    }

    /// Drop expired leases; returns how many were evicted (orphans).
    pub fn expire(&mut self, now: VirtualTime) -> usize {
        let before = self.locks.len();
        self.locks.retain(|_, l| l.expires > now);
        before - self.locks.len()
    }

    /// Active (unexpired) locks on a path.
    pub fn holders(&self, path: &str, now: VirtualTime) -> Vec<&LockRec> {
        self.locks.values().filter(|l| l.path == path && l.expires > now).collect()
    }

    /// Drop every lock owned by `owner` (client unmount / crash cleanup).
    pub fn release_owner(&mut self, owner: u64) -> usize {
        let before = self.locks.len();
        self.locks.retain(|_, l| l.owner != owner);
        before - self.locks.len()
    }

    pub fn len(&self) -> usize {
        self.locks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.locks.is_empty()
    }
}

/// One lease held by the client.
#[derive(Debug, Clone, PartialEq)]
pub struct HeldLease {
    pub token: u64,
    pub path: String,
    pub kind: LockKind,
    pub expires: VirtualTime,
}

/// Client-side lease bookkeeping: which remote locks we hold and which are
/// due for renewal. The client calls [`LeaseManager::due_for_renewal`] at
/// op boundaries (its "periodic renewal") and sends `LockRenew` for each.
#[derive(Debug, Default)]
pub struct LeaseManager {
    held: HashMap<u64, HeldLease>,
    renew_fraction: f64,
    lease_s: f64,
}

impl LeaseManager {
    pub fn new(lease_s: f64, renew_fraction: f64) -> Self {
        LeaseManager { held: HashMap::new(), renew_fraction, lease_s }
    }

    pub fn granted(&mut self, token: u64, path: &str, kind: LockKind, expires: VirtualTime) {
        self.held.insert(token, HeldLease { token, path: path.to_string(), kind, expires });
    }

    pub fn renewed(&mut self, token: u64, expires: VirtualTime) {
        if let Some(l) = self.held.get_mut(&token) {
            l.expires = expires;
        }
    }

    pub fn released(&mut self, token: u64) {
        self.held.remove(&token);
    }

    /// Tokens past the renewal point: remaining lease below
    /// `(1 - renew_fraction)` of the full lease.
    pub fn due_for_renewal(&self, now: VirtualTime) -> Vec<u64> {
        let threshold = self.lease_s * (1.0 - self.renew_fraction);
        self.held
            .values()
            .filter(|l| l.expires.saturating_sub(now).as_secs() <= threshold)
            .map(|l| l.token)
            .collect()
    }

    /// Leases that already lapsed (e.g. while disconnected) — the client
    /// must treat these locks as lost.
    pub fn expired(&self, now: VirtualTime) -> Vec<u64> {
        self.held.values().filter(|l| l.expires <= now).map(|l| l.token).collect()
    }

    pub fn drop_expired(&mut self, now: VirtualTime) -> usize {
        let before = self.held.len();
        self.held.retain(|_, l| l.expires > now);
        before - self.held.len()
    }

    pub fn token_for(&self, path: &str) -> Option<u64> {
        self.held.values().find(|l| l.path == path).map(|l| l.token)
    }

    /// Snapshot of every held lease — the reconnect path re-acquires
    /// each on the (possibly different) serving endpoint, under a fresh
    /// token (the old table died with the crash/failover).
    pub fn held_leases(&self) -> Vec<HeldLease> {
        self.held.values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.held.len()
    }

    pub fn is_empty(&self) -> bool {
        self.held.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs(s)
    }

    #[test]
    fn exclusive_conflicts() {
        let mut lt = LockTable::new(30.0);
        let a = lt.acquire("/f", LockKind::Exclusive, 1, t(0.0));
        assert!(matches!(a, Acquire::Granted { .. }));
        assert_eq!(lt.acquire("/f", LockKind::Exclusive, 2, t(1.0)), Acquire::Denied { holder: 1 });
        assert_eq!(lt.acquire("/f", LockKind::Shared, 2, t(1.0)), Acquire::Denied { holder: 1 });
        assert!(matches!(lt.acquire("/g", LockKind::Exclusive, 2, t(1.0)), Acquire::Granted { .. }));
    }

    #[test]
    fn shared_locks_coexist_but_block_exclusive() {
        let mut lt = LockTable::new(30.0);
        assert!(matches!(lt.acquire("/f", LockKind::Shared, 1, t(0.0)), Acquire::Granted { .. }));
        assert!(matches!(lt.acquire("/f", LockKind::Shared, 2, t(0.0)), Acquire::Granted { .. }));
        assert!(matches!(lt.acquire("/f", LockKind::Exclusive, 3, t(0.0)), Acquire::Denied { .. }));
        assert_eq!(lt.holders("/f", t(0.0)).len(), 2);
    }

    #[test]
    fn lease_expiry_frees_orphans() {
        let mut lt = LockTable::new(30.0);
        let Acquire::Granted { token, .. } = lt.acquire("/f", LockKind::Exclusive, 1, t(0.0)) else {
            panic!()
        };
        // crashed client never renews; after the lease lapses another
        // client gets the lock
        assert!(matches!(lt.acquire("/f", LockKind::Exclusive, 2, t(31.0)), Acquire::Granted { .. }));
        assert_eq!(lt.expire(t(31.0)), 1);
        assert!(lt.renew(token, 1, t(31.0)).is_none());
    }

    #[test]
    fn renew_extends() {
        let mut lt = LockTable::new(30.0);
        let Acquire::Granted { token, .. } = lt.acquire("/f", LockKind::Exclusive, 1, t(0.0)) else {
            panic!()
        };
        let e = lt.renew(token, 1, t(20.0)).unwrap();
        assert_eq!(e, t(50.0));
        assert!(lt.renew(token, 9, t(21.0)).is_none());
        assert!(!lt.release(token, 9));
        assert!(lt.release(token, 1));
        assert!(lt.is_empty());
    }

    #[test]
    fn token_progressions_are_disjoint_across_shards() {
        // shard i of n mints tokens i+1, i+1+n, i+1+2n, ... so the server
        // can route a bare renew/release token back to its shard
        let n = 4u64;
        let mut tables: Vec<LockTable> =
            (0..n).map(|i| LockTable::with_tokens(30.0, i + 1, n)).collect();
        let mut seen = std::collections::HashSet::new();
        for (i, lt) in tables.iter_mut().enumerate() {
            for k in 0..3 {
                let Acquire::Granted { token, .. } =
                    lt.acquire(&format!("/f{k}"), LockKind::Shared, 1, t(0.0))
                else {
                    panic!()
                };
                assert_eq!((token - 1) % n, i as u64, "token {token} routes to shard {i}");
                assert!(seen.insert(token), "token {token} minted twice");
            }
        }
    }

    #[test]
    fn release_owner_cleanup() {
        let mut lt = LockTable::new(30.0);
        lt.acquire("/a", LockKind::Shared, 1, t(0.0));
        lt.acquire("/b", LockKind::Shared, 1, t(0.0));
        lt.acquire("/c", LockKind::Shared, 2, t(0.0));
        assert_eq!(lt.release_owner(1), 2);
        assert_eq!(lt.len(), 1);
    }

    #[test]
    fn same_owner_reacquire_not_self_conflicting() {
        let mut lt = LockTable::new(30.0);
        lt.acquire("/f", LockKind::Exclusive, 1, t(0.0));
        assert!(matches!(lt.acquire("/f", LockKind::Exclusive, 1, t(1.0)), Acquire::Granted { .. }));
    }

    #[test]
    fn manager_renewal_schedule() {
        let mut lm = LeaseManager::new(30.0, 0.5);
        lm.granted(7, "/f", LockKind::Exclusive, t(30.0));
        assert!(lm.due_for_renewal(t(0.0)).is_empty());
        assert_eq!(lm.due_for_renewal(t(16.0)), vec![7]);
        lm.renewed(7, t(46.0));
        assert!(lm.due_for_renewal(t(16.0)).is_empty());
        assert!(lm.expired(t(50.0)).contains(&7));
        assert_eq!(lm.drop_expired(t(50.0)), 1);
        assert!(lm.is_empty());
    }

    #[test]
    fn manager_token_lookup() {
        let mut lm = LeaseManager::new(30.0, 0.5);
        lm.granted(3, "/x", LockKind::Shared, t(30.0));
        assert_eq!(lm.token_for("/x"), Some(3));
        lm.released(3);
        assert_eq!(lm.token_for("/x"), None);
    }
}
