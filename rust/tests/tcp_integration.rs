//! Integration tests: the full XUFS stack over REAL TCP sockets — USSH
//! challenge-response, striped range fetches, push-mode callbacks,
//! meta-op replay and crash recovery, exactly as the e2e example runs it.

use std::sync::{Arc, Mutex};

use xufs::auth::{self, Authenticator, KeyPair};
use xufs::client::{OpenFlags, ServerLink, Vfs, XufsClient};
use xufs::config::{ServerConfig, XufsConfig};
use xufs::coordinator::net::{TcpLink, TcpServer};
use xufs::homefs::FileStore;
use xufs::metrics::Metrics;
use xufs::proto::{FrameDecoder, FrameWriter, Request, Response, BUSY_CODE, MAX_FRAME};
use xufs::runtime::DigestEngine;
use xufs::server::{FileServer, Role};
use xufs::simnet::{RealClock, VirtualTime};
use xufs::util::Rng;
use xufs::vdisk::DiskModel;

struct Rig {
    tcp: TcpServer,
    server: Arc<FileServer>,
    pair: KeyPair,
    cfg: XufsConfig,
    engine: Arc<DigestEngine>,
    metrics: Metrics,
}

fn rig(files: &[(&str, Vec<u8>)]) -> Rig {
    rig_with(files, None)
}

/// `scfg: Some(..)` pins an explicit `[server]` config through
/// `TcpServer::spawn_with`; `None` uses `TcpServer::spawn`. Both serve
/// with the reactor core — the only serving core since the
/// thread-per-connection path was removed.
fn rig_with(files: &[(&str, Vec<u8>)], scfg: Option<&ServerConfig>) -> Rig {
    let metrics = Metrics::new();
    let engine = Arc::new(DigestEngine::native(metrics.clone()));
    let mut rng = Rng::new(1234);
    let pair = KeyPair::generate(&mut rng, VirtualTime::ZERO, 3600.0);
    let mut home = FileStore::default();
    home.mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
    for (p, d) in files {
        home.mkdir_p(&xufs::util::path::parent(p), VirtualTime::ZERO).unwrap();
        home.write(p, d, VirtualTime::ZERO).unwrap();
    }
    let cfg = XufsConfig::default();
    let server = Arc::new(FileServer::new(
        home,
        DiskModel::new(1e12, 0.0),
        engine.clone(),
        64 * 1024,
        2.0, // short leases so orphan expiry is testable
        cfg.server.shards,
        metrics.clone(),
        cfg.chunkstore.clone(),
    ));
    let auth = Arc::new(Mutex::new(Authenticator::new(pair.clone(), 77)));
    let tcp = match scfg {
        Some(s) => TcpServer::spawn_with(server.clone(), auth, metrics.clone(), s).expect("bind"),
        None => TcpServer::spawn(server.clone(), auth, metrics.clone()).expect("bind"),
    };
    Rig { tcp, server, pair, cfg, engine, metrics }
}

/// Read framed responses off a raw blocking socket.
fn next_response(stream: &mut std::net::TcpStream, dec: &mut FrameDecoder) -> Response {
    loop {
        if let Some(frame) = dec.next_frame().expect("framing") {
            return Response::decode(frame).expect("response decode");
        }
        let n = dec.read_from(stream).expect("read from server");
        assert!(n > 0, "server closed the connection");
    }
}

/// A bare authenticated connection driven through the public codec — the
/// tests' stand-in for a hand-rolled (possibly misbehaving) client.
fn raw_handshake(
    addr: std::net::SocketAddr,
    pair: &KeyPair,
) -> (std::net::TcpStream, FrameDecoder, FrameWriter) {
    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10))).ok();
    let mut dec = FrameDecoder::new(MAX_FRAME);
    let mut w = FrameWriter::new();
    w.frame(|e| Request::AuthHello { key_id: pair.key_id.clone() }.encode_into(e));
    assert!(w.flush_to(&mut stream).unwrap());
    let nonce = match next_response(&mut stream, &mut dec) {
        Response::Challenge { nonce } => nonce,
        r => panic!("expected challenge, got {r:?}"),
    };
    let proof = auth::prove(&pair.phrase, &pair.key_id, &nonce);
    w.frame(|e| Request::AuthProof { key_id: pair.key_id.clone(), proof }.encode_into(e));
    assert!(w.flush_to(&mut stream).unwrap());
    match next_response(&mut stream, &mut dec) {
        Response::AuthOk { .. } => {}
        r => panic!("expected auth ok, got {r:?}"),
    }
    (stream, dec, w)
}

impl Rig {
    fn client(&self, id: u64) -> XufsClient<TcpLink> {
        let link = TcpLink::connect(
            self.tcp.addr,
            self.pair.clone(),
            self.cfg.clone(),
            id,
            "/home/u",
            self.metrics.clone(),
        )
        .expect("connect");
        XufsClient::new(
            link,
            self.cfg.clone(),
            self.engine.clone(),
            Arc::new(RealClock::new()),
            "/home/u",
            self.metrics.clone(),
        )
    }
}

#[test]
fn striped_fetch_is_bit_exact() {
    let mut rng = Rng::new(5);
    let mut big = vec![0u8; 8 << 20];
    rng.fill_bytes(&mut big);
    let r = rig(&[("/home/u/big.bin", big.clone())]);
    let mut c = r.client(1);
    let fd = c.open("/home/u/big.bin", OpenFlags::rdonly()).unwrap();
    let mut got = Vec::new();
    let mut chunk = vec![0u8; 1 << 20];
    loop {
        let n = c.read(fd, &mut chunk).unwrap();
        if n == 0 {
            break;
        }
        got.extend_from_slice(&chunk[..n]);
    }
    c.close(fd).unwrap();
    assert_eq!(got.len(), big.len());
    assert!(got == big, "striped reassembly must be bit-exact");
}

#[test]
fn writeback_and_cross_client_callback() {
    let r = rig(&[("/home/u/doc.txt", b"v1".to_vec())]);
    let mut a = r.client(1);
    let mut b = r.client(2);
    a.scan_file("/home/u/doc.txt", 4096).unwrap();
    b.scan_file("/home/u/doc.txt", 4096).unwrap();
    // a writes; the server pushes an invalidation to b
    a.write_file("/home/u/doc.txt", b"v2 from a", 4096).unwrap();
    // wait for the push to cross the socket
    for _ in 0..100 {
        std::thread::sleep(std::time::Duration::from_millis(10));
        b.tick();
        if b.cache().entry("/home/u/doc.txt").map(|e| e.state) != Some(xufs::cache::EntryState::Clean) {
            break;
        }
    }
    let fd = b.open("/home/u/doc.txt", OpenFlags::rdonly()).unwrap();
    let mut fresh = [0u8; 64];
    let n = b.read(fd, &mut fresh).unwrap();
    b.close(fd).unwrap();
    assert_eq!(&fresh[..n], b"v2 from a");
}

#[test]
fn auth_rejects_wrong_phrase() {
    let r = rig(&[]);
    let mut bad_pair = r.pair.clone();
    bad_pair.phrase[0] ^= 0xFF;
    let res = TcpLink::connect(
        r.tcp.addr,
        bad_pair,
        r.cfg.clone(),
        9,
        "/home/u",
        r.metrics.clone(),
    );
    assert!(res.is_err(), "bad phrase must be rejected");
    // and a good client still connects fine afterwards
    let mut c = r.client(1);
    c.write_file("/home/u/ok.txt", b"fine", 64).unwrap();
    assert!(r.server.home().exists("/home/u/ok.txt"));
}

#[test]
fn challenge_response_protocol_level() {
    // drive the raw protocol: prove() with the right phrase verifies,
    // replaying the same proof fails (nonce single-use)
    let mut rng = Rng::new(3);
    let pair = KeyPair::generate(&mut rng, VirtualTime::ZERO, 60.0);
    let mut a = Authenticator::new(pair.clone(), 4);
    let n1 = a.challenge(&pair.key_id);
    let proof = auth::prove(&pair.phrase, &pair.key_id, &n1);
    assert!(a.verify_proof(&pair.key_id, &proof, VirtualTime::ZERO).is_some());
    assert!(a.verify_proof(&pair.key_id, &proof, VirtualTime::ZERO).is_none());
}

#[test]
fn client_crash_recovery_over_tcp() {
    let r = rig(&[("/home/u/base.txt", b"base".to_vec())]);
    let mut c = r.client(1);
    c.writeback = xufs::client::WritebackMode::Async;
    c.write_file("/home/u/wip1.txt", b"work one", 4096).unwrap();
    c.write_file("/home/u/wip2.txt", b"work two", 4096).unwrap();
    assert!(c.queue_len() >= 2);
    assert!(!r.server.home().exists("/home/u/wip1.txt"));
    let snapshot = c.cache_store_snapshot();
    drop(c); // crash

    let link = TcpLink::connect(r.tcp.addr, r.pair.clone(), r.cfg.clone(), 3, "/home/u", r.metrics.clone())
        .unwrap();
    let (c2, corrupt) = XufsClient::recover(
        link,
        r.cfg.clone(),
        r.engine.clone(),
        Arc::new(RealClock::new()),
        "/home/u",
        snapshot,
        r.metrics.clone(),
    );
    assert_eq!(corrupt, 0);
    assert_eq!(c2.queue_len(), 0, "recovery replays the queue");
    assert_eq!(r.server.home().read("/home/u/wip1.txt").unwrap(), b"work one");
    assert_eq!(r.server.home().read("/home/u/wip2.txt").unwrap(), b"work two");
}

#[test]
fn server_restart_and_reconnect() {
    let r = rig(&[("/home/u/f.txt", b"hello".to_vec())]);
    let mut c = r.client(1);
    c.scan_file("/home/u/f.txt", 4096).unwrap();
    // server process "crashes" (state except disk lost) and restarts
    r.server.crash();
    r.server.restart();
    // cached read still fine
    assert_eq!(c.scan_file("/home/u/f.txt", 4096).unwrap(), 5);
    // reconnect re-registers the callback channel; writes flow again
    c.link_mut().reconnect().unwrap();
    c.write_file("/home/u/after.txt", b"back", 4096).unwrap();
    assert!(r.server.home().exists("/home/u/after.txt"));
}

#[test]
fn lock_lease_conflict_and_orphan_expiry_over_tcp() {
    let r = rig(&[("/home/u/shared.dat", vec![0u8; 128])]);
    let mut a = r.client(1);
    let mut b = r.client(2);
    let fa = a.open("/home/u/shared.dat", OpenFlags::rdwr()).unwrap();
    a.lock(fa, xufs::proto::LockKind::Exclusive).unwrap();
    let fb = b.open("/home/u/shared.dat", OpenFlags::rdwr()).unwrap();
    assert!(b.lock(fb, xufs::proto::LockKind::Exclusive).is_err(), "conflict expected");
    // a "crashes" without releasing; the 2s lease lapses and b succeeds
    drop(a);
    std::thread::sleep(std::time::Duration::from_millis(2300));
    b.lock(fb, xufs::proto::LockKind::Exclusive).expect("orphaned lock must expire");
}

#[test]
fn torn_striped_fetch_detected_via_version() {
    // a FetchRange with a stale expect_version must be refused
    let r = rig(&[("/home/u/v.bin", vec![1u8; 256 * 1024])]);
    let resp = r.server.handle(
        1,
        Request::FetchRange {
            path: "/home/u/v.bin".into(),
            offset: 0,
            len: 1024,
            expect_version: 999,
        },
        VirtualTime::ZERO,
    );
    assert!(matches!(resp, Response::Err { code: 116, .. }), "{resp:?}");
}

/// `TcpLink` endpoint rotation (SimLink parity, DESIGN.md §2.7): a
/// standby endpoint's code-112 registration refusal rotates the connect
/// to the primary, and a later demotion severs the control socket so the
/// caller's reconnect rotates again — all over real sockets.
#[test]
fn endpoint_rotation_on_standby_and_demotion() {
    // both rigs derive the same deterministic key pair, so one credential
    // is valid at either endpoint (as with a real replicated deployment)
    let ra = rig(&[]);
    let rb = rig(&[]);
    rb.server.set_role(Role::Secondary);
    let metrics = Metrics::new();
    // endpoint list leads with the standby: the connect must rotate past
    let link = TcpLink::connect_endpoints(
        vec![rb.tcp.addr, ra.tcp.addr],
        ra.pair.clone(),
        ra.cfg.clone(),
        7,
        "/home/u",
        metrics.clone(),
    )
    .expect("rotation past the standby endpoint");
    assert_eq!(link.active_endpoint(), ra.tcp.addr);
    assert_eq!(metrics.counter(xufs::metrics::names::REPLICA_FAILOVERS), 1);
    let mut c = XufsClient::new(
        link,
        ra.cfg.clone(),
        ra.engine.clone(),
        Arc::new(RealClock::new()),
        "/home/u",
        metrics.clone(),
    );
    c.write_file("/home/u/on-a.txt", b"primary", 4096).unwrap();
    assert!(ra.server.home().exists("/home/u/on-a.txt"));
    // failover: A retires, B is promoted. A's code-112 reply severs the
    // control connection; the explicit reconnect rotates to B.
    rb.server.set_role(Role::Primary);
    ra.server.set_role(Role::Retired);
    assert!(c.write_file("/home/u/stranded.txt", b"x", 4096).is_err());
    c.link_mut().reconnect().expect("reconnect rotates to the new primary");
    assert_eq!(c.link_mut().active_endpoint(), rb.tcp.addr);
    assert!(metrics.counter(xufs::metrics::names::REPLICA_FAILOVERS) >= 2);
    c.write_file("/home/u/on-b.txt", b"new primary", 4096).unwrap();
    assert!(rb.server.home().exists("/home/u/on-b.txt"));
}

/// Directed stalled-client test (DESIGN.md §2.9 backpressure): a peer
/// that pipelines more response bytes than the write high-water mark and
/// refuses to read gets paused — it throttles only itself, other clients
/// stay fast — and once it finally drains, every queued response arrives
/// bit-exact (partial-write resumption never tears a frame).
#[test]
fn stalled_reader_throttles_only_itself_then_drains_intact() {
    let mut rng = Rng::new(9);
    let mut big = vec![0u8; 8 << 20];
    rng.fill_bytes(&mut big);
    let r = rig(&[("/home/u/big.bin", big.clone())]);
    let (mut s, mut dec, mut w) = raw_handshake(r.tcp.addr, &r.pair);
    w.frame(|e| Request::FetchMeta { path: "/home/u/big.bin".into(), min_version: 0 }.encode_into(e));
    assert!(w.flush_to(&mut s).unwrap());
    let version = match next_response(&mut s, &mut dec) {
        Response::FileMeta { version, .. } => version,
        resp => panic!("expected meta, got {resp:?}"),
    };
    // 24 x 384 KiB = 9 MiB of queued responses, past the 4 MiB high-water
    // mark (and under the 32-request in-flight cap)
    const RANGES: u64 = 24;
    const LEN: u64 = 384 * 1024;
    for i in 0..RANGES {
        let offset = (i % 21) * LEN; // stay inside the 8 MiB file
        w.frame(|e| {
            Request::FetchRange { path: "/home/u/big.bin".into(), offset, len: LEN, expect_version: version }
                .encode_into(e)
        });
    }
    assert!(w.flush_to(&mut s).unwrap());
    // stall: don't read. Give the server time to hit the high-water mark,
    // then prove other clients are unaffected while this peer is paused.
    std::thread::sleep(std::time::Duration::from_millis(300));
    let mut b = r.client(2);
    for i in 0..10 {
        b.write_file(&format!("/home/u/fast{i}.txt"), b"not throttled", 4096).unwrap();
    }
    // now drain everything: all 24 responses, every block bit-exact
    let bb = 64 * 1024usize;
    let mut got = 0u64;
    let mut bytes = 0u64;
    while got < RANGES {
        match next_response(&mut s, &mut dec) {
            Response::FileBlocks { extents, .. } => {
                assert!(!extents.is_empty());
                for e in &extents {
                    let at = e.index as usize * bb;
                    assert_eq!(&e.data[..], &big[at..at + e.data.len()], "block {} torn", e.index);
                    bytes += e.data.len() as u64;
                }
                got += 1;
            }
            resp => panic!("expected blocks, got {resp:?}"),
        }
    }
    assert_eq!(bytes, RANGES * LEN, "every queued byte must arrive");
}

/// Admission control: past `[server] max_connections` a new peer gets the
/// typed busy frame ([`xufs::proto::BUSY_CODE`]) and is dropped — and a
/// freed slot is admitted again.
#[test]
fn admission_control_refuses_with_busy_code() {
    let mut scfg = XufsConfig::default().server;
    scfg.max_connections = 2;
    let r = rig_with(&[], Some(&scfg));
    let keep1 = raw_handshake(r.tcp.addr, &r.pair);
    let _keep2 = raw_handshake(r.tcp.addr, &r.pair);
    // third connection: refused before any handshake, with the busy frame
    let mut s3 = std::net::TcpStream::connect(r.tcp.addr).expect("connect");
    s3.set_read_timeout(Some(std::time::Duration::from_secs(10))).ok();
    let mut dec = FrameDecoder::new(MAX_FRAME);
    let resp = next_response(&mut s3, &mut dec);
    assert!(matches!(resp, Response::Err { code: BUSY_CODE, .. }), "{resp:?}");
    assert!(r.metrics.counter(xufs::metrics::names::SERVER_BACKPRESSURE_REJECTS) >= 1);
    // a disconnect frees the slot; the next connect is admitted
    drop(keep1);
    std::thread::sleep(std::time::Duration::from_millis(200));
    let _readmitted = raw_handshake(r.tcp.addr, &r.pair);
}

/// The full replicated stack over real sockets (DESIGN.md §2.7/§2.11):
/// a primary and a secondary rig, a background shipper daemon streaming
/// the primary's log over a replication-plane `TcpLink`, two clients
/// hammering ~10k mixed ops — with the primary killed and restarted
/// mid-run — and at quiesce the secondary's store is byte-exact with
/// the primary's.
#[test]
fn replicated_soak_over_tcp_converges_byte_exact() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use xufs::replica::Shipper;

    let ra = rig(&[]);
    let rb = rig(&[]);
    rb.server.set_role(Role::Secondary);
    rb.server.enable_replication();
    ra.server.enable_replication();

    // shipper daemon: drains the primary's durable log to the secondary
    // every few milliseconds, riding through errors with a reconnect
    let stop = Arc::new(AtomicBool::new(false));
    let daemon = {
        let primary = ra.server.clone();
        let stop = stop.clone();
        let metrics = ra.metrics.clone();
        let link = TcpLink::connect_replication(
            rb.tcp.addr,
            ra.pair.clone(),
            ra.cfg.clone(),
            Metrics::new(),
        )
        .expect("replication link to the secondary");
        std::thread::spawn(move || {
            let mut sh = Shipper::new(link, 64);
            while !stop.load(Ordering::SeqCst) {
                if sh.ship(&primary, &metrics).is_err() {
                    let _ = sh.link_mut().reconnect();
                    let _ = sh.resync();
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            sh
        })
    };

    let mut clients = vec![ra.client(1), ra.client(2)];
    let mut rng = Rng::new(0x50AC_2026);
    const STEPS: usize = 10_000;
    for step in 0..STEPS {
        // mid-run primary kill: ops fail while it is down, the shipper
        // keeps draining the durable log, clients reconnect after the
        // restart and replay their queues (server-side seq dedup makes
        // the replay exactly-once, so the mirror stays exact)
        if step == 6_000 {
            ra.server.crash();
        }
        if step == 6_150 {
            ra.server.restart();
            for c in clients.iter_mut() {
                while c.link_mut().reconnect().is_err() {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            }
        }
        let i = (rng.below(2)) as usize;
        let f = format!("/home/u/f{}", rng.below(48));
        match rng.below(10) {
            0..=5 => {
                let mut data = vec![0u8; (1 + rng.below(2048)) as usize];
                rng.fill_bytes(&mut data);
                let _ = clients[i].write_file(&f, &data, 1024);
            }
            6..=7 => {
                let _ = clients[i].scan_file(&f, 4096);
            }
            8 => {
                let _ = clients[i].unlink(&f);
            }
            _ => {
                let _ = clients[i].fsync();
            }
        }
    }
    // quiesce: every client queue drained at the primary
    for c in clients.iter_mut() {
        for _ in 0..100 {
            if c.fsync().is_ok() && c.queue_len() == 0 {
                break;
            }
            let _ = c.link_mut().reconnect();
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(c.queue_len(), 0, "client queue must drain at quiesce");
    }
    stop.store(true, Ordering::SeqCst);
    let mut sh = daemon.join().expect("shipper daemon");
    // final drain: nothing the clients applied may be missing
    for _ in 0..100 {
        match sh.ship(&ra.server, &ra.metrics) {
            Ok(0) => break,
            Ok(_) => {}
            Err(_) => {
                let _ = sh.link_mut().reconnect();
                let _ = sh.resync();
            }
        }
    }
    assert_eq!(sh.lag(&ra.server), 0, "secondary fully caught up");

    // byte-exact convergence: same paths, kinds, sizes, versions, bytes
    // (mtimes differ by design — the mirror applies at ship time)
    let fingerprint = |s: &FileServer| -> Vec<String> {
        let guard = s.home();
        let mut out = Vec::new();
        for (path, attr) in guard.walk("/").expect("walk") {
            let content = match attr.kind {
                xufs::homefs::NodeKind::File => {
                    let data = guard.read(&path).expect("read");
                    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
                    for b in &data {
                        h ^= *b as u64;
                        h = h.wrapping_mul(0x0000_0100_0000_01b3);
                    }
                    format!("{} bytes, fnv {h:016x}", data.len())
                }
                xufs::homefs::NodeKind::Dir => "dir".to_string(),
            };
            out.push(format!("{path} v{} {:?} {} [{content}]", attr.version, attr.kind, attr.size));
        }
        out
    };
    let a = fingerprint(&ra.server);
    let b = fingerprint(&rb.server);
    assert!(a.len() > 2, "the soak must have created files");
    let diff: Vec<&String> = a
        .iter()
        .filter(|x| !b.contains(x))
        .chain(b.iter().filter(|x| !a.contains(x)))
        .collect();
    assert!(diff.is_empty(), "secondary mirror diverges: {diff:?}");
}

#[test]
fn prefetch_over_tcp_pulls_directory() {
    let mut files: Vec<(String, Vec<u8>)> = Vec::new();
    for i in 0..30 {
        files.push((format!("/home/u/src/f{i:02}.c"), format!("int x{i};\n").into_bytes()));
    }
    let refs: Vec<(&str, Vec<u8>)> = files.iter().map(|(p, d)| (p.as_str(), d.clone())).collect();
    let r = rig(&refs);
    let mut c = r.client(1);
    c.chdir("/home/u/src").unwrap();
    // all 30 small files prefetched over the worker pool
    assert_eq!(c.metrics().counter(xufs::metrics::names::PREFETCH_FILES), 30);
    // and every open afterwards is a cache hit
    for i in 0..30 {
        c.scan_file(&format!("/home/u/src/f{i:02}.c"), 4096).unwrap();
    }
    assert_eq!(c.metrics().counter(xufs::metrics::names::CACHE_MISSES), 0);
}
