//! Disconnected-operation hardening: the randomized fault-schedule
//! explorer plus directed failure-plane tests (DESIGN.md §2.5, §2.7).
//!
//! The explorer drives 2 clients + 1 server — or, on the replicated
//! topology, 2 clients + a primary with 2–3 SERVING secondaries
//! (DESIGN.md §2.11: per-seed fleet size, log shipping to every one,
//! bounded-staleness reads routed to a random replica per op, and
//! primary-crash/promote schedule events) — through hundreds of seeded
//! fault schedules (dropped/duplicated/delayed packets, torn transfers,
//! multi-step partitions, server crash/restart, client crash/recovery,
//! failover, bit-rot byte flips in durable artifacts) and checks the
//! convergence invariants after a quiesce:
//!
//!   I1  no dirty block is ever lost: every surviving successful close is
//!       byte-identical at the authoritative home space (last close wins
//!       — the PROMOTED SECONDARY after a failover);
//!   I2  no op applies twice and nothing resurrects: each client's home
//!       directory holds exactly the files the model predicts, with no
//!       spurious conflict files — across crash, replay AND failover;
//!   I3  all replicas converge: after quiesce, every client reads every
//!       file byte-identical to the authority, and (un-promoted fleets)
//!       EVERY secondary's store mirrors the primary's byte- and
//!       version-identically once shipping drains;
//!   I4  no secondary ever serves state ahead of its replication
//!       watermark: for every path its shipped log governs, its version
//!       is exactly what the log prescribes at the watermark, and paths
//!       first created beyond the watermark are absent;
//!   I5  no client ever observes bytes whose digest mismatches the
//!       version it was told it read: every injected byte flip is
//!       DETECTED — surfaced as a repair-from-replica, a cache-block
//!       demotion, a dropped op-log record, or a typed `Corrupted`
//!       refusal — never served as data, never a panic (DESIGN.md
//!       §2.10; the byte-exact I1/I3 sweeps are what catch a leak);
//!   I6  no client ever observes a path's version moving BACKWARDS
//!       (DESIGN.md §2.11): across replica switches, too-stale
//!       fallbacks and promotions, each client session's per-path
//!       high-water version only grows — the bounded-staleness floor
//!       (`min_version` on the read RPCs) is what enforces it. The
//!       oracle resets per path on that client's own unlink/rename and
//!       wholesale on client crash-recovery (monotonic reads are a
//!       SESSION property; versions legitimately restart at 1 on
//!       unlink+recreate).
//!
//! A failing schedule reproduces deterministically from its printed seed:
//!
//! ```text
//! FAULT_SEED=<seed> cargo test --test fault_properties fault_schedule_explorer
//! ```

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

use xufs::client::{OpenFlags, ServerLink, Vfs, WritebackMode, XufsClient};
use xufs::config::{FaultConfig, XufsConfig};
use xufs::coordinator::{SimLink, SimWorld};
use xufs::homefs::FsError;
use xufs::metrics::names;
use xufs::proto::{LockKind, MetaOp, ReplPayload};
use xufs::simnet::{CorruptArtifact, FaultEvent, FaultPlan, VirtualTime};
use xufs::util::Rng;

fn t(s: f64) -> VirtualTime {
    VirtualTime::from_secs(s)
}

/// The chaos profile the explorer runs under: every fault class enabled
/// at rates high enough that a 60-op schedule hits several of them.
fn chaos_profile() -> FaultConfig {
    FaultConfig {
        enabled: true,
        drop_request_p: 0.06,
        drop_reply_p: 0.06,
        duplicate_p: 0.05,
        delay_p: 0.05,
        delay_max_ms: 150,
        interrupt_p: 0.05,
        partition_p: 0.02,
        partition_max_steps: 20,
        server_crash_p: 0.01,
        server_crash_max_steps: 12,
        client_crash_p: 0.01,
        // 0 keeps pre-replica schedules byte-identical per seed (no
        // extra die is rolled); the replicated explorer turns it up
        promote_after_crash_p: 0.0,
        // bit rot in durable artifacts (DESIGN.md §2.10): a 60-op
        // schedule flips a byte somewhere a handful of times, and I5
        // demands every flip is detected, never served
        corrupt_p: 0.02,
    }
}

/// The replicated topology's profile: same chaos, plus half of all
/// primary crashes escalate to a promote decision (DESIGN.md §2.7).
fn replica_chaos_profile() -> FaultConfig {
    FaultConfig { promote_after_crash_p: 0.5, ..chaos_profile() }
}

/// Retry a mutating op until it succeeds, reconnecting between attempts
/// (every attempt advances the fault schedule, so partitions drain).
fn with_retries(
    c: &mut XufsClient<SimLink>,
    what: &str,
    mut op: impl FnMut(&mut XufsClient<SimLink>) -> Result<(), FsError>,
) -> Result<(), String> {
    for _ in 0..25 {
        if op(c).is_ok() {
            return Ok(());
        }
        let _ = c.link_mut().reconnect();
    }
    Err(format!("{what} kept failing"))
}

fn read_all(c: &mut XufsClient<SimLink>, path: &str) -> Result<Vec<u8>, FsError> {
    let fd = c.open(path, OpenFlags::rdonly())?;
    let mut out = Vec::new();
    let mut buf = vec![0u8; 8192];
    loop {
        match c.read(fd, &mut buf) {
            Ok(0) => break,
            Ok(n) => out.extend_from_slice(&buf[..n]),
            Err(e) => {
                let _ = c.close(fd);
                return Err(e);
            }
        }
    }
    c.close(fd)?;
    Ok(out)
}

/// I6 oracle: after a SUCCESSFUL read of `path` by client `i`, the
/// version its cache now holds must be at least the highest version
/// that session ever observed for the path. The per-path entry resets
/// when the client itself unlinks/renames the path (versions restart at
/// 1 on recreate) and the whole map resets on crash-recovery (monotonic
/// reads are a session property).
fn observe_read(
    c: &XufsClient<SimLink>,
    hw: &mut BTreeMap<String, u64>,
    i: usize,
    path: &str,
) -> Result<(), String> {
    let Some(v) = c.cache().entry(path).map(|e| e.version) else { return Ok(()) };
    if let Some(prev) = hw.get(path) {
        if v < *prev {
            return Err(format!(
                "I6: client {i} observed {path} moving backwards: v{v} after v{prev}"
            ));
        }
    }
    hw.insert(path.to_string(), v);
    Ok(())
}

/// I4 (replicated topology, un-promoted): the secondary never serves
/// state ahead of its replication watermark. For every path governed by
/// a shipped `Op` record, the secondary's version must be exactly what
/// the log prescribes at its watermark; a path whose FIRST record lies
/// beyond the watermark (and which the initial snapshot lacked) must be
/// absent. Paths touched by `Local` records are skipped (those carry no
/// version), as are conflict side-writes (not in the log at all).
fn check_i4(world: &SimWorld, initial_paths: &BTreeSet<String>) -> Result<(), String> {
    if world.is_promoted() {
        return Ok(());
    }
    for sec in world.secondaries() {
        check_i4_on(world, sec, initial_paths)?;
    }
    Ok(())
}

/// [`check_i4`] against ONE secondary, at whatever watermark its own
/// shipper reached (the fleet's replicas lag independently).
fn check_i4_on(
    world: &SimWorld,
    sec: &xufs::server::FileServer,
    initial_paths: &BTreeSet<String>,
) -> Result<(), String> {
    let w = sec.repl_ship_seq();
    // Seed the per-path fold with the effects retained from the
    // truncated acked prefix (DESIGN.md §2.8): those records were by
    // definition shipped and acked (ship_seq <= watermark), and without
    // the seed a path FIRST created inside the truncated prefix would be
    // misjudged as "first created beyond the watermark".
    let (mut expect, mut untracked): (BTreeMap<String, Option<u64>>, BTreeSet<String>) =
        world.server.repl_truncated_summary();
    let log = world.server.repl_records_after(0, usize::MAX);
    let mut beyond: BTreeSet<String> = BTreeSet::new();
    for rec in &log {
        let within = rec.ship_seq <= w;
        match &rec.payload {
            ReplPayload::Op { new_version, op, .. } => match op {
                MetaOp::Rename { from, to } => {
                    if within {
                        expect.insert(from.clone(), None);
                        expect.insert(to.clone(), Some(*new_version));
                    } else if !expect.contains_key(to) && !initial_paths.contains(to) {
                        beyond.insert(to.clone());
                    }
                }
                MetaOp::Unlink { path } | MetaOp::Rmdir { path } => {
                    if within {
                        expect.insert(path.clone(), None);
                    }
                }
                _ => {
                    let p = op.path().to_string();
                    if within {
                        expect.insert(p, Some(*new_version));
                    } else if !expect.contains_key(&p) && !initial_paths.contains(&p) {
                        beyond.insert(p);
                    }
                }
            },
            ReplPayload::Local { op } => {
                untracked.insert(op.path().to_string());
            }
            ReplPayload::Failed { .. } => {}
        }
    }
    for (path, want) in &expect {
        if untracked.contains(path) {
            continue;
        }
        let got = sec.home().stat(path).ok().map(|a| a.version);
        let ok = match (got, want) {
            (Some(v), Some(exp)) => v == *exp,
            (None, None) => true,
            _ => false,
        };
        if !ok {
            return Err(format!(
                "I4: secondary serves {path} at {got:?} but its watermark {w} prescribes {want:?}"
            ));
        }
    }
    for path in beyond {
        if untracked.contains(&path) {
            continue;
        }
        if sec.home().exists(&path) {
            return Err(format!(
                "I4: secondary serves {path}, first created beyond its watermark {w}"
            ));
        }
    }
    Ok(())
}

/// Un-promoted replicated quiesce: once shipping drains, EVERY
/// secondary's store must mirror the primary's — same paths, kinds,
/// sizes, versions and bytes (mtimes differ: the mirror applies at ship
/// time).
fn check_replica_mirror(world: &SimWorld) -> Result<(), String> {
    if world.is_promoted() {
        return Ok(());
    }
    let fingerprint = |s: &xufs::server::FileServer| -> Result<Vec<String>, String> {
        let guard = s.home();
        let mut out = Vec::new();
        for (path, attr) in guard.walk("/").map_err(|e| format!("walk: {e}"))? {
            let content = match attr.kind {
                xufs::homefs::NodeKind::File => {
                    let data = guard.read(&path).map_err(|e| format!("read {path}: {e}"))?;
                    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
                    for b in &data {
                        h ^= *b as u64;
                        h = h.wrapping_mul(0x0000_0100_0000_01b3);
                    }
                    format!("{} bytes, fnv {h:016x}", data.len())
                }
                xufs::homefs::NodeKind::Dir => "dir".to_string(),
            };
            out.push(format!("{path} v{} {:?} {} [{content}]", attr.version, attr.kind, attr.size));
        }
        Ok(out)
    };
    let a = fingerprint(&world.server)?;
    for (j, sec) in world.secondaries().iter().enumerate() {
        let b = fingerprint(sec)?;
        if a != b {
            let diff: Vec<&String> = a
                .iter()
                .filter(|x| !b.contains(x))
                .chain(b.iter().filter(|x| !a.contains(x)))
                .collect();
            return Err(format!("I3: secondary {j} mirror diverges from primary: {diff:?}"));
        }
    }
    Ok(())
}

/// One seeded schedule: randomized ops on 2 clients under the fault
/// plane, then quiesce and check the convergence invariants. `shards`
/// pins the server's namespace shard count (DESIGN.md §2.6) so the same
/// invariants are model-checked against both the sharded core and the
/// single-lock ablation; `replica` stands up a primary plus 2–3 SERVING
/// secondaries (per-seed fleet size) with log shipping to every one,
/// bounded-staleness read fan-out, and primary-crash/promote schedule
/// events (DESIGN.md §2.7/§2.11).
fn run_schedule(seed: u64, ops: usize, shards: usize, replica: bool) -> Result<(), String> {
    let mut cfg = XufsConfig::default();
    cfg.seed = seed;
    cfg.fault = if replica { replica_chaos_profile() } else { chaos_profile() };
    cfg.server.shards = shards;
    if replica {
        // 2 or 3 serving secondaries per seed; a tight staleness bound
        // so the server-side gate (code 119 on lag) actually bites
        cfg.replica.secondaries = 2 + (seed % 2) as usize;
        cfg.replica.read_fanout = true;
        cfg.replica.staleness_ops = 8;
    }
    let mut world = SimWorld::new(cfg.clone());
    world.home(|s| {
        let now = VirtualTime::ZERO;
        s.home_mut().mkdir_p("/home/u/c0", now).unwrap();
        s.home_mut().mkdir_p("/home/u/c1", now).unwrap();
        s.home_mut().write("/home/u/shared0", &vec![0xA5u8; 100_000], now).unwrap();
        s.home_mut().write("/home/u/shared1", b"shared doc\n", now).unwrap();
    });
    let mut initial_paths: BTreeSet<String> = BTreeSet::new();
    if replica {
        world.enable_replica();
        initial_paths = world
            .secondary()
            .expect("replica enabled")
            .home()
            .walk("/")
            .map_err(|e| format!("walk: {e}"))?
            .into_iter()
            .map(|(p, _)| p)
            .collect();
    }
    // mount cleanly, then arm the fault plane on both links
    let mut clients = Vec::new();
    for _ in 0..2 {
        let mut c = world.mount("/home/u").map_err(|e| format!("mount: {e}"))?;
        c.writeback = WritebackMode::Async;
        c.async_flush_threshold = 3;
        clients.push(c);
    }
    let plan = Arc::new(Mutex::new(FaultPlan::new(seed, cfg.fault.clone())));
    world.set_fault_plan(plan.clone());
    for c in &mut clients {
        c.link_mut().set_faults(plan.clone());
    }

    // expected home content per client dir, updated on every SUCCESSFUL
    // local operation (each client writes a disjoint subtree, so the
    // final home state is exactly the per-client last-close truth)
    let mut model: Vec<BTreeMap<String, Vec<u8>>> = vec![BTreeMap::new(), BTreeMap::new()];
    // I6 oracle: per-client per-path high-water version (see observe_read)
    let mut high_water: Vec<BTreeMap<String, u64>> = vec![BTreeMap::new(), BTreeMap::new()];
    let mut rng = Rng::new(seed ^ 0x5EED_CAFE);

    for op_no in 0..ops as u64 {
        let i = rng.below(2) as usize;
        // a real client keeps trying to come back; every attempt also
        // advances the schedule, so partitions and crashes always end
        if !clients[i].link().is_connected() {
            let _ = clients[i].link_mut().reconnect();
        }
        if replica {
            // route this op's reads at a random endpoint: 0 = the
            // default lowest-RTT replica, k = replica k pinned — so
            // every seed exercises every serving secondary AND the
            // too-stale/fenced/down fallbacks from each of them
            let n = world.secondaries().len() as u64;
            let pref = match rng.below(n + 1) {
                0 => None,
                k => Some(k as usize),
            };
            clients[i].link_mut().set_read_preference(pref);
        }
        let file = format!("/home/u/c{i}/f{}", rng.below(4));
        match rng.below(20) {
            0..=7 | 18..=19 => {
                // whole-file write of fresh unique content; the close is
                // local (write-behind), so only rare flush-path errors
                // need the retry
                let mut data = vec![0u8; rng.range(16, 4096) as usize];
                rng.fill_bytes(&mut data);
                data.extend_from_slice(format!("#{seed}/{op_no}").as_bytes());
                with_retries(&mut clients[i], &format!("write_file {file}"), |c| {
                    c.write_file(&file, &data, 1024)
                })?;
                model[i].insert(file.clone(), data);
            }
            8..=9 => {
                if clients[i].scan_file(&file, 4096).is_ok() {
                    observe_read(&clients[i], &mut high_water[i], i, &file)?;
                }
            }
            10..=11 => {
                let shared = format!("/home/u/shared{}", rng.below(2));
                if clients[i].scan_file(&shared, 8192).is_ok() {
                    observe_read(&clients[i], &mut high_water[i], i, &shared)?;
                }
            }
            12..=13 => {
                if model[i].contains_key(&file) {
                    with_retries(&mut clients[i], &format!("unlink {file}"), |c| {
                        c.unlink(&file)
                    })?;
                    model[i].remove(&file);
                    // the client removed the path itself: a recreate
                    // legitimately restarts versions at 1
                    high_water[i].remove(&file);
                }
            }
            14 => {
                if model[i].contains_key(&file) {
                    let to = format!("/home/u/c{i}/r{op_no}");
                    with_retries(&mut clients[i], &format!("rename {file}"), |c| {
                        c.rename(&file, &to)
                    })?;
                    let data = model[i].remove(&file).unwrap();
                    model[i].insert(to, data);
                    high_water[i].remove(&file);
                }
            }
            15 => {
                let _ = clients[i].fsync();
            }
            16 => {
                world.server_tick();
                clients[i].think(0.5);
            }
            _ => {
                // spill-class write: exercises the by-reference op-log
                // records surviving crashes
                let mut data = vec![0u8; 300 * 1024];
                rng.fill_bytes(&mut data[..64]);
                data.extend_from_slice(format!("#{seed}/{op_no}").as_bytes());
                with_retries(&mut clients[i], &format!("big write_file {file}"), |c| {
                    c.write_file(&file, &data, 65536)
                })?;
                model[i].insert(file.clone(), data);
            }
        }
        // harness-level schedule events: client crashes (snapshot the
        // cache space, drop the process, recover under the SAME identity
        // from the durable log) and — replicated topology — the decision
        // to promote the secondary after a primary crash. (Take the
        // events in their own statement — holding the plan lock across
        // mount_recovered/promote would deadlock on fault_step.)
        let events = plan.lock().unwrap().take_harness_events();
        for ev in events {
            match ev {
                FaultEvent::ClientCrash { client } => {
                    let idx = client as usize % clients.len();
                    let snap = clients[idx].cache_store_snapshot();
                    let id = clients[idx].link().client_id();
                    let mut back = None;
                    for _ in 0..5000 {
                        if let Ok((c2, _corrupt)) = world.mount_recovered("/home/u", &snap, id) {
                            back = Some(c2);
                            break;
                        }
                    }
                    let Some(mut c2) = back else {
                        return Err("crashed client could not re-mount".into());
                    };
                    c2.writeback = WritebackMode::Async;
                    c2.async_flush_threshold = 3;
                    clients[idx] = c2;
                    // a fresh session: monotonic-read state resets (I6)
                    high_water[idx].clear();
                }
                FaultEvent::PromoteSecondary => {
                    if !replica {
                        continue;
                    }
                    // the operator's failover: drain the durable log to
                    // the secondary and promote it. Every failed attempt
                    // (partitioned/refused shipping) advances the
                    // schedule, so the drain eventually gets through.
                    let mut promoted = false;
                    for _ in 0..5000 {
                        if world.promote_secondary().is_ok() {
                            promoted = true;
                            break;
                        }
                    }
                    if !promoted {
                        return Err("promote could not complete".into());
                    }
                }
                FaultEvent::CorruptByte { artifact, sel } => {
                    // Bit rot (DESIGN.md §2.10). Chunk rot is only
                    // injected where the repair plane can heal it: the
                    // primary's copy of a chunk the secondary also
                    // holds. Unreplicated runs — and post-failover
                    // worlds, where the surviving pair member IS the
                    // authority — retarget the flip at a client's
                    // cache disk instead.
                    let artifact = if matches!(artifact, CorruptArtifact::Chunk)
                        && (!replica || world.is_promoted())
                    {
                        CorruptArtifact::Cache
                    } else {
                        artifact
                    };
                    match artifact {
                        CorruptArtifact::Chunk => {
                            if world.corrupt_shared_chunk(sel).is_some() {
                                // heal inline: the scrub quarantines the
                                // rotted copy and the repair plane
                                // refetches it from the secondary; every
                                // failed round (partition, severed link)
                                // advances the schedule
                                let mut healed = false;
                                for _ in 0..5000 {
                                    if matches!(world.repair_tick(), Ok(0)) {
                                        healed = true;
                                        break;
                                    }
                                }
                                if !healed {
                                    return Err("chunk repair could not complete".into());
                                }
                            }
                        }
                        CorruptArtifact::Cache | CorruptArtifact::Oplog => {
                            // rot on a client disk. Drain the victim's
                            // queue first: a dirty block or unacked
                            // op-log record is the ONLY copy of that
                            // data — integrity detection protects
                            // durable REDUNDANT state, it cannot
                            // resurrect bytes that never reached the
                            // home space. A world too broken to drain
                            // right now means the flip misses.
                            let idx = (sel % clients.len() as u64) as usize;
                            let mut drained = false;
                            for _ in 0..200 {
                                if !clients[idx].link().is_connected()
                                    && clients[idx].link_mut().reconnect().is_err()
                                {
                                    continue;
                                }
                                if clients[idx].fsync().is_ok() && clients[idx].queue_len() == 0 {
                                    drained = true;
                                    break;
                                }
                                let _ = clients[idx].link_mut().reconnect();
                            }
                            if !drained {
                                continue;
                            }
                            let mut snap = clients[idx].cache_store_snapshot();
                            let hit = match artifact {
                                CorruptArtifact::Oplog => {
                                    snap.corrupt_file_byte(xufs::metaq::OPLOG_PATH, sel >> 16)
                                }
                                _ => snap.corrupt_dense_byte(sel).is_some(),
                            };
                            if !hit {
                                continue;
                            }
                            // crash + recover on the rotted disk: the
                            // recovery pass must DETECT the flip (demote
                            // the block, drop the record) and never
                            // panic; the final I1–I3 sweeps prove the
                            // client re-faulted truth instead of
                            // serving rot (I5)
                            let id = clients[idx].link().client_id();
                            let mut back = None;
                            for _ in 0..5000 {
                                if let Ok((c2, _)) = world.mount_recovered("/home/u", &snap, id) {
                                    back = Some(c2);
                                    break;
                                }
                            }
                            let Some(mut c2) = back else {
                                return Err("rotted client could not re-mount".into());
                            };
                            c2.writeback = WritebackMode::Async;
                            c2.async_flush_threshold = 3;
                            clients[idx] = c2;
                            high_water[idx].clear();
                        }
                    }
                }
            }
        }
        // steady-state log shipping (bounded lag): rides the WAN and the
        // fault plane like any other interaction
        if replica {
            world.replica_tick(false);
        }
    }

    // ---- quiesce: stop injecting, heal the world, drain every queue ----
    plan.lock().unwrap().quiesce();
    if !world.server.is_up() {
        world.server_restart();
    }
    for c in clients.iter_mut() {
        // reconnect AND drain: after a failover the client may come back
        // bound to the fenced ex-primary (restarted, up, refusing) —
        // a drained queue on a serving endpoint is the real success
        // condition, and each failed round rotates endpoints
        let mut drained = false;
        for _ in 0..50 {
            if !c.link().is_connected() && c.link_mut().reconnect().is_err() {
                continue;
            }
            if c.fsync().is_ok() && c.queue_len() == 0 {
                drained = true;
                break;
            }
            let _ = c.link_mut().reconnect();
        }
        if !drained {
            return Err("client could not reconnect+drain during quiesce".into());
        }
    }
    world.server_tick();
    for c in clients.iter_mut() {
        c.tick();
        c.fsync().map_err(|e| format!("quiesce fsync 2: {e}"))?;
        if c.queue_len() != 0 {
            return Err(format!("queue not drained after quiesce: {} ops left", c.queue_len()));
        }
    }

    // ---- replication: settle the pair before judging invariants ----
    if replica {
        // I4 first, at whatever lag the schedule left behind (the
        // watermark oracle bites precisely when lag > 0)...
        check_i4(&world, &initial_paths)?;
        if !world.is_promoted() {
            // ...then drain fully and require a byte+version mirror
            let mut left = u64::MAX;
            for _ in 0..200 {
                left = world.replica_tick(true);
                if left == 0 {
                    break;
                }
            }
            if left != 0 {
                return Err(format!("replication could not drain at quiesce ({left} ops left)"));
            }
            check_replica_mirror(&world)?;
            check_i4(&world, &initial_paths)?;
        }
    }

    // ---- invariants, judged against the AUTHORITY (the promoted
    // secondary after a failover, the primary otherwise) ----
    let authority = world.authority();
    for (i, m) in model.iter().enumerate() {
        // I1: no dirty block lost, last close wins
        for (path, want) in m {
            let home = authority
                .home()
                .read(path)
                .map(|d| d.to_vec())
                .map_err(|e| format!("I1: home lost {path}: {e}"))?;
            if &home != want {
                return Err(format!(
                    "I1: home diverged at {path}: {} bytes vs expected {}",
                    home.len(),
                    want.len()
                ));
            }
        }
        // I2: nothing applied twice, nothing resurrected, no spurious
        // conflicts in a single-writer subtree
        let listing: Vec<String> = authority
            .home()
            .readdir(&format!("/home/u/c{i}"))
            .map(|v| v.into_iter().map(|(n, _)| n).collect())
            .map_err(|e| format!("I2: readdir c{i}: {e}"))?;
        for name in &listing {
            let p = format!("/home/u/c{i}/{name}");
            if name.contains(".xufs-conflict-") {
                return Err(format!("I2: spurious conflict file {p} in single-writer dir"));
            }
            if !m.contains_key(&p) {
                return Err(format!("I2: unexpected file {p} at home"));
            }
        }
        if listing.len() != m.len() {
            return Err(format!(
                "I2: c{i} home dir has {} entries, model has {}",
                listing.len(),
                m.len()
            ));
        }
    }
    // I3: every replica reads every file byte-identical to home (the
    // reads still fan out under each client's last pinned preference,
    // so the I6 oracle also crosses the drained replicas here)
    for ci in 0..clients.len() {
        for m in &model {
            for (path, want) in m {
                let got = read_all(&mut clients[ci], path)
                    .map_err(|e| format!("I3: client {ci} cannot read {path}: {e}"))?;
                if &got != want {
                    return Err(format!("I3: client {ci} reads stale/divergent {path}"));
                }
                observe_read(&clients[ci], &mut high_water[ci], ci, path)?;
            }
        }
    }
    // I5: no undetected rot survives the schedule — a full scrub of the
    // authority's chunk table quarantines nothing (every injected flip
    // was healed or refused before quiesce), and the byte-exact I1/I3
    // sweeps above already proved no client ever read rotted data
    let bad = authority.scrub_all_chunks();
    if !bad.is_empty() {
        return Err(format!("I5: {} chunk(s) still rotted after quiesce", bad.len()));
    }
    Ok(())
}

fn seed_override() -> Option<u64> {
    std::env::var("FAULT_SEED").ok().and_then(|s| s.parse().ok())
}

fn explore(seeds: std::ops::Range<u64>, ops: usize) {
    explore_cfg(seeds, ops, XufsConfig::default().server.shards, false)
}

fn explore_with_shards(seeds: std::ops::Range<u64>, ops: usize, shards: usize) {
    explore_cfg(seeds, ops, shards, false)
}

fn explore_cfg(seeds: std::ops::Range<u64>, ops: usize, shards: usize, replica: bool) {
    if let Some(seed) = seed_override() {
        if let Err(msg) = run_schedule(seed, ops, shards, replica) {
            panic!("schedule seed {seed} violated an invariant: {msg}");
        }
        return;
    }
    let mut failures: Vec<(u64, String)> = Vec::new();
    let total = seeds.end - seeds.start;
    for seed in seeds {
        if let Err(msg) = run_schedule(seed, ops, shards, replica) {
            failures.push((seed, msg));
        }
    }
    if !failures.is_empty() {
        let (seed, msg) = &failures[0];
        panic!(
            "{}/{} fault schedules violated invariants; first: seed {seed}: {msg}\n  \
             reproduce: FAULT_SEED={seed} cargo test --test fault_properties fault_schedule_explorer",
            failures.len(),
            total,
        );
    }
}

/// The fast, deterministic fault matrix: 220 seeded schedules (CI's
/// `fault-matrix` job runs exactly this).
#[test]
fn fault_schedule_explorer() {
    explore(0xFA17_0000..0xFA17_0000 + 220, 60);
}

/// The nightly-class long run: more seeds, longer schedules.
#[test]
#[ignore = "long fault matrix; run with --ignored (nightly CI) or FAULT_SEED=<seed> for one schedule"]
fn fault_schedule_explorer_long() {
    explore(0xFA17_8000..0xFA17_8000 + 1000, 120);
}

/// Invariants I1–I3 pinned at `shards = 4` (DESIGN.md §2.6): the sharded
/// concurrent core preserves the whole PR 3 fault plane — watermarks and
/// conflict preservation live per shard, and these 50 schedules prove no
/// seeded interleaving of drops, duplicates, partitions, crashes and
/// recoveries can tell the difference.
#[test]
fn fault_schedule_explorer_sharded_core() {
    explore_with_shards(0xFA17_4000..0xFA17_4000 + 50, 60, 4);
}

/// The same 50 schedules against the `shards = 1` ablation — the scale
/// bench's baseline server is held to the identical failure model.
#[test]
fn fault_schedule_explorer_single_shard_ablation() {
    explore_with_shards(0xFA17_4000..0xFA17_4000 + 50, 60, 1);
}

/// The REPLICATED fault matrix (DESIGN.md §2.7/§2.11): 220 seeded
/// schedules on the 2-clients + primary + 2–3 SERVING secondaries
/// topology — log shipping to every replica rides the same WAN faults,
/// bounded-staleness reads route to a random replica per op (falling
/// back on too-stale/fenced/down refusals), primary crashes escalate to
/// a promote decision half the time, clients fail over with full replay
/// of their unacked op logs. Invariants I1–I3 are re-proven against
/// whichever node ends up authoritative, plus I4 (no secondary serves
/// ahead of its watermark), I5 and I6 (no client session ever observes
/// a version move backwards — across replica switches AND promotions).
/// CI's `failover-matrix` job runs exactly this; a failing schedule
/// reproduces with
/// `FAULT_SEED=<seed> cargo test --test fault_properties fault_schedule_explorer_replicated`.
#[test]
fn fault_schedule_explorer_replicated() {
    explore_cfg(0xFA17_2000..0xFA17_2000 + 220, 60, XufsConfig::default().server.shards, true);
}

/// Nightly-class replicated long run (more seeds, longer schedules).
#[test]
#[ignore = "long replicated fault matrix; run with --ignored (nightly CI) or FAULT_SEED=<seed>"]
fn fault_schedule_explorer_replicated_long() {
    explore_cfg(
        0xFA17_A000..0xFA17_A000 + 500,
        120,
        XufsConfig::default().server.shards,
        true,
    );
}

// ---------------------------------------------------------------------
// directed failure-plane tests
// ---------------------------------------------------------------------

/// Flagship disconnected-conflict case: the home copy changes while a
/// disconnected client edits the same file. On reconnect the client's
/// close wins (last-close-wins), but the home-side edit is preserved as
/// a `.xufs-conflict-<client>-<seq>` file instead of being silently dropped.
#[test]
fn disconnected_conflict_preserves_loser_at_home() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/doc", b"draft at home\n", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.scan_file("/home/u/doc", 1024).unwrap();
    c.link_mut().set_network(false);
    c.write_file("/home/u/doc", b"edited at the site while offline\n", 1024).unwrap();
    assert!(c.queue_len() > 0, "disconnected close queues the write");
    // the user edits the same file at home during the outage
    world.home(|s| s.local_write("/home/u/doc", b"edited at home during the outage\n", t(5.0)).unwrap());
    c.link_mut().set_network(true);
    c.link_mut().reconnect().unwrap();
    c.fsync().unwrap();
    assert_eq!(c.queue_len(), 0);
    // last close wins at the path itself...
    let home = world.home(|s| s.home().read("/home/u/doc").unwrap().to_vec());
    assert_eq!(home, b"edited at the site while offline\n");
    // ...and the loser survives beside it
    let conflicts: Vec<String> = world.home(|s| {
        s.home()
            .readdir("/home/u")
            .unwrap()
            .into_iter()
            .map(|(n, _)| n)
            .filter(|n| n.contains(".xufs-conflict-"))
            .collect()
    });
    assert_eq!(conflicts.len(), 1, "exactly one conflict file: {conflicts:?}");
    let loser =
        world.home(|s| s.home().read(&format!("/home/u/{}", conflicts[0])).unwrap().to_vec());
    assert_eq!(loser, b"edited at home during the outage\n");
    assert_eq!(world.metrics.counter(names::CONFLICT_FILES), 1);
}

/// An uncontended disconnected replay (home copy untouched during the
/// outage) must not leave a conflict file even though the write carries
/// conflict-detection context.
#[test]
fn uncontended_disconnected_replay_leaves_no_conflict() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/doc", b"v1", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.scan_file("/home/u/doc", 1024).unwrap();
    c.link_mut().set_network(false);
    c.write_file("/home/u/doc", b"offline edit", 1024).unwrap();
    // nothing edits the file at home during the outage: on replay the
    // base version still matches the server's, so even though the write
    // carries conflict-detection context, no conflict is recorded
    c.link_mut().set_network(true);
    c.link_mut().reconnect().unwrap();
    c.fsync().unwrap();
    let names_at_home: Vec<String> =
        world.home(|s| s.home().readdir("/home/u").unwrap().into_iter().map(|(n, _)| n).collect());
    assert!(
        names_at_home.iter().all(|n| !n.contains(".xufs-conflict-")),
        "no conflict for an uncontended replay: {names_at_home:?}"
    );
    assert_eq!(world.metrics.counter(names::CONFLICT_FILES), 0);
}

/// Satellite regression: replay must SKIP ops whose target vanished
/// while disconnected instead of erroring the whole queue — both when
/// the target's parent was removed at home, and when the client itself
/// unlinked the file behind a queued write.
#[test]
fn ghost_replay_skips_vanished_targets_and_drains_queue() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u/sub", t(0.0)).unwrap();
        s.home_mut().write("/home/u/sub/f", b"cached", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.writeback = WritebackMode::Async;
    c.async_flush_threshold = usize::MAX;
    c.scan_file("/home/u/sub/f", 1024).unwrap();
    c.link_mut().set_network(false);
    // ghost class 1: queued write whose home-side parent dir vanishes
    c.write_file("/home/u/sub/f", b"offline", 1024).unwrap();
    // ghost class 2: the client itself unlinks behind its queued write
    c.write_file("/home/u/gone.txt", b"create, write...", 1024).unwrap();
    c.unlink("/home/u/gone.txt").unwrap();
    // an innocent bystander queued after the ghosts
    c.write_file("/home/u/kept.txt", b"survives", 1024).unwrap();
    // meanwhile the user removes /home/u/sub at home entirely
    world.home(|s| {
        s.home_mut().unlink("/home/u/sub/f", t(5.0)).unwrap();
        s.home_mut().rmdir("/home/u/sub", t(5.0)).unwrap();
    });
    c.link_mut().set_network(true);
    c.link_mut().reconnect().unwrap();
    c.fsync().unwrap();
    assert_eq!(c.queue_len(), 0, "ghosts must not wedge the queue");
    assert!(c.metrics().counter(names::METAQ_REPLAY_SKIPPED) >= 1);
    world.home(|s| {
        assert!(!s.home().exists("/home/u/sub/f"));
        assert!(!s.home().exists("/home/u/gone.txt"));
        assert_eq!(s.home().read("/home/u/kept.txt").unwrap(), b"survives");
    });
}

/// Acceptance: a client crash with a non-empty durable op log replays to
/// a byte-identical namespace on restart — including spill-class writes
/// recovered by reference — and replaying ops the server already applied
/// (lost replies) does not re-apply them.
#[test]
fn client_crash_with_dirty_oplog_replays_byte_identical() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.writeback = WritebackMode::Async;
    c.async_flush_threshold = usize::MAX;
    let mut rng = Rng::new(0xD1E);
    let mut big = vec![0u8; 400 * 1024];
    rng.fill_bytes(&mut big);
    c.write_file("/home/u/small.txt", b"small dirty write", 1024).unwrap();
    c.write_file("/home/u/big.bin", &big, 65536).unwrap();
    c.write_file("/home/u/victim.txt", b"doomed", 1024).unwrap();
    c.unlink("/home/u/victim.txt").unwrap();
    c.rename("/home/u/small.txt", "/home/u/renamed.txt").unwrap();
    assert!(c.queue_len() > 0, "the durable op log is non-empty");
    // crash before any flush; the cache space (parallel FS) survives
    let snap = c.cache_store_snapshot();
    let id = c.link().client_id();
    drop(c);
    let (c2, corrupt) = world.mount_recovered("/home/u", &snap, id).unwrap();
    assert_eq!(corrupt, 0);
    assert_eq!(c2.queue_len(), 0, "recovery replays the whole log");
    world.home(|s| {
        assert_eq!(s.home().read("/home/u/renamed.txt").unwrap(), b"small dirty write");
        assert_eq!(s.home().read("/home/u/big.bin").unwrap(), &big[..]);
        assert!(!s.home().exists("/home/u/victim.txt"));
        assert!(!s.home().exists("/home/u/small.txt"));
    });

    // now the lost-reply shape: everything applies server-side but no
    // ack comes back; a crash + recovery replays duplicates, which the
    // idempotence watermark must swallow without re-applying
    let mut c2 = c2;
    c2.writeback = WritebackMode::Async;
    c2.async_flush_threshold = usize::MAX;
    c2.write_file("/home/u/twice.txt", b"must apply exactly once", 1024).unwrap();
    let reply_loss = FaultConfig { enabled: true, drop_reply_p: 1.0, ..Default::default() };
    let plan = Arc::new(Mutex::new(FaultPlan::new(7, reply_loss)));
    world.set_fault_plan(plan.clone());
    c2.link_mut().set_faults(plan.clone());
    let _ = c2.fsync(); // applied at the server; replies lost
    assert!(c2.queue_len() > 0, "no acks -> ops stay queued");
    let v_applied = world.home(|s| s.home().stat("/home/u/twice.txt").unwrap().version);
    plan.lock().unwrap().quiesce();
    let snap2 = c2.cache_store_snapshot();
    let id2 = c2.link().client_id();
    drop(c2);
    let (c3, corrupt2) = world.mount_recovered("/home/u", &snap2, id2).unwrap();
    assert_eq!(corrupt2, 0);
    assert_eq!(c3.queue_len(), 0);
    world.home(|s| {
        assert_eq!(s.home().read("/home/u/twice.txt").unwrap(), b"must apply exactly once");
        assert_eq!(
            s.home().stat("/home/u/twice.txt").unwrap().version,
            v_applied,
            "duplicate replay must not re-apply (version bump) the write"
        );
    });
}

/// Satellite: crash-recovery of the residency map under 10 seeds — a
/// client killed between `pwrite` and `close` loses only the unmerged
/// shadow bytes (cleaned up by recovery), while exactly the entries
/// whose persisted residency token was torn demote to Invalid.
#[test]
fn residency_recovery_demotes_exactly_torn_entries() {
    for seed in 0..10u64 {
        let mut world = SimWorld::new(XufsConfig::default());
        let mut originals: Vec<Vec<u8>> = Vec::new();
        let mut rng = Rng::new(0xBEEF ^ seed);
        world.home(|s| {
            s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        });
        for i in 0..6 {
            let mut data = vec![0u8; 150_000];
            rng.fill_bytes(&mut data);
            world.home(|s| {
                s.home_mut().write(&format!("/home/u/f{i}"), &data, t(0.0)).unwrap()
            });
            originals.push(data);
        }
        let mut c = world.mount("/home/u").unwrap();
        for i in 0..6 {
            c.scan_file(&format!("/home/u/f{i}"), 65536).unwrap();
        }
        // interrupted writers: pwrite lands in the shadow, close never runs
        let mut torn_writes = Vec::new();
        for i in 0..3usize {
            if rng.chance(0.6) {
                let fd = c.open(&format!("/home/u/f{i}"), OpenFlags::rdwr()).unwrap();
                c.pwrite(fd, &vec![0xEE; 1000], 64 * 1024 * (i as u64 % 2)).unwrap();
                torn_writes.push(i);
                // fd intentionally left open: the crash happens here
            }
        }
        let mut snap = c.cache_store_snapshot();
        let id = c.link().client_id();
        drop(c);
        let had_shadows =
            snap.walk("/").unwrap().iter().any(|(p, _)| p.contains(".xufs.shadow."));
        assert_eq!(
            had_shadows,
            !torn_writes.is_empty(),
            "seed {seed}: interrupted writers leave shadows behind"
        );
        // torn attr files: the crash tore the persisted residency token
        // of some OTHER entries mid-write
        let mut torn_tokens = Vec::new();
        for i in 3..6usize {
            if rng.chance(0.6) {
                let apath = format!("/home/u/.xufs.attr.f{i}");
                let txt = String::from_utf8_lossy(&snap.read(&apath).unwrap()).to_string();
                let bad = txt.replace("\"residency\":\"", "\"residency\":\"!torn ");
                assert_ne!(bad, txt, "tamper must hit the residency token");
                snap.write(&apath, bad.as_bytes(), t(9.0)).unwrap();
                torn_tokens.push(i);
            }
        }
        let demoted_before = world.metrics.counter(names::CACHE_RECOVER_DEMOTED);
        let (mut c2, corrupt) = world.mount_recovered("/home/u", &snap, id).unwrap();
        assert_eq!(corrupt, 0, "seed {seed}: the op log itself is intact");
        assert_eq!(
            world.metrics.counter(names::CACHE_RECOVER_DEMOTED) - demoted_before,
            torn_tokens.len() as u64,
            "seed {seed}: recover() demotes exactly the torn entries"
        );
        assert_eq!(c2.queue_len(), 0, "seed {seed}: un-closed writes queue nothing");
        // torn-token entries are Invalid (refetched on demand); everything
        // reads back the ORIGINAL content — unmerged pwrites are gone per
        // POSIX un-closed-write semantics
        for i in &torn_tokens {
            let state = c2.cache().entry(&format!("/home/u/f{i}")).unwrap().state;
            assert_eq!(state, xufs::cache::EntryState::Invalid, "seed {seed}: f{i}");
        }
        for i in 0..6usize {
            let got = read_all(&mut c2, &format!("/home/u/f{i}")).unwrap();
            assert_eq!(got, originals[i], "seed {seed}: f{i} content");
        }
        // orphaned shadow files were swept by recovery
        let store = c2.cache_store_snapshot();
        let shadows: Vec<String> = store
            .walk("/")
            .unwrap()
            .into_iter()
            .map(|(p, _)| p)
            .filter(|p| p.contains(".xufs.shadow."))
            .collect();
        assert!(shadows.is_empty(), "seed {seed}: orphaned shadows remain: {shadows:?}");
    }
}

/// Satellite: a lock lease lapses while its holder is partitioned away.
/// The server frees the lock for others; after the partition heals the
/// old holder must revalidate before serving cached reads.
#[test]
fn lease_expiry_during_partition_forces_revalidation() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/doc", b"locked content v1", t(0.0)).unwrap();
    });
    let mut a = world.mount("/home/u").unwrap();
    let mut b = world.mount("/home/u").unwrap();
    a.scan_file("/home/u/doc", 1024).unwrap();
    let fd_a = a.open("/home/u/doc", OpenFlags::rdonly()).unwrap();
    a.lock(fd_a, LockKind::Exclusive).unwrap();
    // partition the holder for far longer than the 30 s lease
    a.link_mut().set_network(false);
    a.think(120.0);
    world.server_tick();
    assert!(world.metrics.counter(names::LEASE_EXPIRED) >= 1, "orphan lease expired");
    // the lock is free: the other client takes it and rewrites the file
    let fd_b = b.open("/home/u/doc", OpenFlags::rdonly()).unwrap();
    b.lock(fd_b, LockKind::Exclusive).unwrap();
    b.unlock(fd_b).unwrap();
    b.close(fd_b).unwrap();
    b.write_file("/home/u/doc", b"rewritten while a was away", 1024).unwrap();
    // the partition heals; the old holder reconnects
    a.link_mut().set_network(true);
    a.link_mut().reconnect().unwrap();
    let rpcs_before = world.wan.stats().rpcs;
    let got = read_all(&mut a, "/home/u/doc").unwrap();
    assert_eq!(got, b"rewritten while a was away", "stale cache must not be served blind");
    assert!(
        world.wan.stats().rpcs > rpcs_before,
        "the read after reconnect must revalidate over the WAN"
    );
    // releasing the dead lease is a no-op server-side, not an error
    a.close(fd_a).unwrap();
    // and a fresh lock acquire succeeds now that the orphan is gone
    let fd_a2 = a.open("/home/u/doc", OpenFlags::rdonly()).unwrap();
    a.lock(fd_a2, LockKind::Exclusive).unwrap();
    a.close(fd_a2).unwrap();
}

// ---------------------------------------------------------------------
// directed failover tests (DESIGN.md §2.7)
// ---------------------------------------------------------------------

/// Conflict files under `/home/u` at one node of the pair.
fn conflicts_at(s: &xufs::server::FileServer) -> Vec<String> {
    s.home()
        .readdir("/home/u")
        .unwrap()
        .into_iter()
        .map(|(n, _)| n)
        .filter(|n| n.contains(".xufs-conflict-"))
        .collect()
}

/// A lease held at primary-crash time re-acquires on the promoted
/// secondary under a FRESH token (lock state is deliberately volatile —
/// the table died with the primary's process), and the lock is genuinely
/// held there: a rival stays denied until the holder releases.
#[test]
fn failover_reacquires_lease_with_fresh_token_on_secondary() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/doc", b"locked content", t(0.0)).unwrap();
    });
    world.enable_replica();
    let mut a = world.mount("/home/u").unwrap();
    let mut b = world.mount("/home/u").unwrap();
    a.scan_file("/home/u/doc", 1024).unwrap();
    let fd_a = a.open("/home/u/doc", OpenFlags::rdonly()).unwrap();
    a.lock(fd_a, LockKind::Exclusive).unwrap();
    let fd_b = b.open("/home/u/doc", OpenFlags::rdonly()).unwrap();
    assert!(matches!(b.lock(fd_b, LockKind::Exclusive), Err(FsError::LockConflict(_))));
    // crash the primary while the lease is held; promote the standby
    world.server_crash();
    world.promote_secondary().unwrap();
    // the holder reconnects: the op-boundary tick re-acquires its lease
    // on the promoted secondary
    a.link_mut().reconnect().unwrap();
    assert_eq!(a.link().active_endpoint(), 1, "holder failed over to the secondary");
    a.tick();
    // the rival fails over too — and is still denied, by name
    b.link_mut().reconnect().unwrap();
    assert_eq!(b.link().active_endpoint(), 1);
    match b.lock(fd_b, LockKind::Exclusive) {
        Err(FsError::LockConflict(msg)) => {
            assert!(msg.contains(&format!("client {}", a.link().client_id())), "{msg}");
        }
        r => panic!("rival lock must stay denied after failover: {r:?}"),
    }
    // releasing through the re-acquired (fresh) token works on the
    // secondary and frees the path for the rival
    a.unlock(fd_a).unwrap();
    b.lock(fd_b, LockKind::Exclusive).unwrap();
    b.close(fd_b).unwrap();
    a.close(fd_a).unwrap();
    assert!(world.metrics.counter(names::REPLICA_FAILOVERS) >= 2);
}

/// Dirty-chain conflict across a failover, reply-loss shape: the
/// disconnected write APPLIED at the primary (conflict preserved there)
/// but every ack was lost, so the client replays it to the promoted
/// secondary. The replicated per-(client,seq) watermark answers the
/// replay as a duplicate — the conflict file exists exactly once at the
/// new authority, not twice.
#[test]
fn failover_replay_preserves_conflict_once_not_twice() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/doc", b"draft at home\n", t(0.0)).unwrap();
    });
    world.enable_replica();
    let mut c = world.mount("/home/u").unwrap();
    c.scan_file("/home/u/doc", 1024).unwrap();
    c.link_mut().set_network(false);
    c.write_file("/home/u/doc", b"edited at the site while offline\n", 1024).unwrap();
    world.home(|s| {
        s.local_write("/home/u/doc", b"edited at home during the outage\n", t(5.0)).unwrap()
    });
    c.link_mut().set_network(true);
    c.link_mut().reconnect().unwrap();
    // the flush applies at the primary — conflict preserved there — but
    // every reply is lost, so the op stays queued (unacked) client-side
    let reply_loss = FaultConfig { enabled: true, drop_reply_p: 1.0, ..Default::default() };
    let plan = Arc::new(Mutex::new(FaultPlan::new(7, reply_loss)));
    world.set_fault_plan(plan.clone());
    c.link_mut().set_faults(plan.clone());
    let _ = c.fsync();
    assert!(c.queue_len() > 0, "acks lost -> op stays queued");
    assert_eq!(conflicts_at(&world.server).len(), 1, "conflict preserved at the primary");
    plan.lock().unwrap().quiesce();
    // ship everything — op, idempotence watermark, conflict file — then
    // crash the primary and promote
    assert_eq!(world.replica_tick(true), 0);
    world.server_crash();
    world.promote_secondary().unwrap();
    c.link_mut().reconnect().unwrap();
    c.fsync().unwrap(); // full replay of the unacked op against the secondary
    assert_eq!(c.queue_len(), 0);
    let authority = world.authority();
    let conflicts = conflicts_at(&authority);
    assert_eq!(conflicts.len(), 1, "exactly one conflict after the failover replay: {conflicts:?}");
    assert_eq!(
        authority.home().read("/home/u/doc").unwrap(),
        b"edited at the site while offline\n"
    );
    assert_eq!(
        authority.home().read(&format!("/home/u/{}", conflicts[0])).unwrap(),
        b"edited at home during the outage\n"
    );
}

/// Dirty-chain conflict across a failover, lag shape: the primary dies
/// BEFORE the disconnected write ever reached it. The failover replay
/// applies the op fresh on the secondary — whose replicated state holds
/// the conflicting home-side edit — so the conflict file is preserved
/// exactly once, at the new authority, while the dead primary never saw
/// the write at all.
#[test]
fn failover_replay_applies_unshipped_op_with_conflict_once() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/doc", b"draft at home\n", t(0.0)).unwrap();
    });
    world.enable_replica();
    let mut c = world.mount("/home/u").unwrap();
    c.scan_file("/home/u/doc", 1024).unwrap();
    c.link_mut().set_network(false);
    c.write_file("/home/u/doc", b"edited at the site while offline\n", 1024).unwrap();
    assert!(c.queue_len() > 0);
    // the home-side edit replicates; then the primary dies with the
    // client still disconnected
    world.home(|s| {
        s.local_write("/home/u/doc", b"edited at home during the outage\n", t(5.0)).unwrap()
    });
    assert_eq!(world.replica_tick(true), 0);
    world.server_crash();
    world.promote_secondary().unwrap();
    c.link_mut().set_network(true);
    c.link_mut().reconnect().unwrap();
    assert_eq!(c.link().active_endpoint(), 1);
    c.fsync().unwrap();
    assert_eq!(c.queue_len(), 0);
    let authority = world.authority();
    let conflicts = conflicts_at(&authority);
    assert_eq!(conflicts.len(), 1, "conflict created exactly once on the secondary: {conflicts:?}");
    assert_eq!(
        authority.home().read("/home/u/doc").unwrap(),
        b"edited at the site while offline\n"
    );
    assert_eq!(
        authority.home().read(&format!("/home/u/{}", conflicts[0])).unwrap(),
        b"edited at home during the outage\n"
    );
    // the fenced primary holds only the pre-crash state: its home-side
    // edit, no conflict file
    assert_eq!(
        world.server.home().read("/home/u/doc").unwrap(),
        b"edited at home during the outage\n"
    );
    assert!(conflicts_at(&world.server).is_empty());
}

/// Torn bulk transfers resume instead of restarting: with every range
/// fetch interrupted mid-flight, a multi-block scan still completes and
/// verifies, with the resumes surfaced in metrics.
#[test]
fn interrupted_transfers_resume_and_complete() {
    let mut world = SimWorld::new(XufsConfig::default());
    let mut data = vec![0u8; 2 << 20];
    let mut rng = Rng::new(42);
    rng.fill_bytes(&mut data);
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/big.bin", &data, t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    let torn_only = FaultConfig { enabled: true, interrupt_p: 1.0, ..Default::default() };
    let plan = Arc::new(Mutex::new(FaultPlan::new(3, torn_only)));
    world.set_fault_plan(plan.clone());
    c.link_mut().set_faults(plan.clone());
    let got = read_all(&mut c, "/home/u/big.bin").unwrap();
    assert_eq!(got, data, "resumed fetch must be byte-identical");
    assert!(
        c.metrics().counter(names::RESUMED_FETCHES) > 0,
        "every transfer was torn; resumes must show up in metrics"
    );
}

// ---------------------------------------------------------------------
// directed chunk-substrate tests (DESIGN.md §2.8)
// ---------------------------------------------------------------------

/// Cross-user dedup: the same toolchain blob written into two users'
/// home dirs is stored physically ONCE — the second copy is all dedup
/// hits, and the savings surface in the metrics.
#[test]
fn dedup_across_two_clients_home_dirs() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u/c0", t(0.0)).unwrap();
        s.home_mut().mkdir_p("/home/u/c1", t(0.0)).unwrap();
    });
    let mut a = world.mount("/home/u").unwrap();
    let mut b = world.mount("/home/u").unwrap();
    let mut blob = vec![0u8; 256 * 1024]; // 4 chunks at the default 64 KiB
    let mut rng = Rng::new(0xDED0);
    rng.fill_bytes(&mut blob);
    a.write_file("/home/u/c0/toolchain.tar", &blob, 65536).unwrap();
    a.fsync().unwrap();
    b.write_file("/home/u/c1/toolchain.tar", &blob, 65536).unwrap();
    b.fsync().unwrap();
    {
        let g = world.server.home();
        let cs = g.chunkstore().expect("chunk substrate is on by default");
        assert_eq!(cs.dedup_hits(), 4, "the second user's copy is pure dedup");
        assert_eq!(cs.dedup_bytes_saved(), blob.len() as u64);
        assert_eq!(cs.stored_bytes(), blob.len() as u64, "two logical copies, one physical");
        assert_eq!(g.read("/home/u/c0/toolchain.tar").unwrap(), blob);
        assert_eq!(g.read("/home/u/c1/toolchain.tar").unwrap(), blob);
    }
    assert_eq!(world.metrics.counter(names::CHUNK_DEDUP_HITS), 4);
    assert_eq!(world.metrics.counter(names::CHUNK_DEDUP_BYTES_SAVED), blob.len() as u64);
}

/// Rename is pure metadata on the chunk substrate: the file keeps its
/// exact chunk list (residency), nothing is re-stored or re-deduped,
/// and the bytes read back identical at the new name.
#[test]
fn rename_is_pure_metadata_and_preserves_chunk_residency() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap());
    let mut c = world.mount("/home/u").unwrap();
    let mut data = vec![0u8; 200 * 1024];
    let mut rng = Rng::new(0x4E4A);
    rng.fill_bytes(&mut data);
    c.write_file("/home/u/before.bin", &data, 65536).unwrap();
    c.fsync().unwrap();
    let (size_before, digests_before, stored_before, hits_before) = {
        let g = world.server.home();
        let (size, ds) = g.file_chunks("/home/u/before.bin").unwrap();
        let cs = g.chunkstore().unwrap();
        (size, ds, cs.stored_bytes(), cs.dedup_hits())
    };
    c.rename("/home/u/before.bin", "/home/u/after.bin").unwrap();
    c.fsync().unwrap();
    let g = world.server.home();
    assert!(!g.exists("/home/u/before.bin"));
    let (size_after, digests_after) = g.file_chunks("/home/u/after.bin").unwrap();
    assert_eq!(size_before, size_after);
    assert_eq!(digests_before, digests_after, "rename moves references, not bytes");
    let cs = g.chunkstore().unwrap();
    assert_eq!(cs.stored_bytes(), stored_before, "no chunk re-stored by the rename");
    assert_eq!(cs.dedup_hits(), hits_before, "nothing went back through the dedup path");
    assert_eq!(g.read("/home/u/after.bin").unwrap(), data);
}

/// GC safety on the replicated pair: a chunk referenced by a snapshot
/// manifest or an un-shipped replication record NEVER collects. Once
/// ref-based shipping drains and the acked prefix truncates, the log
/// pins release — and the sweep then frees exactly the chunks nothing
/// references, while the snapshot keeps serving its frozen bytes.
#[test]
fn gc_never_collects_snapshot_or_unshipped_log_pinned_chunks() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap());
    world.enable_replica();
    let mut c = world.mount("/home/u").unwrap();
    let mut rng = Rng::new(0x6C6C);
    let mut v1 = vec![0u8; 192 * 1024];
    rng.fill_bytes(&mut v1);
    c.write_file("/home/u/data.bin", &v1, 65536).unwrap();
    c.fsync().unwrap();
    let v1_digests = world.server.home().file_chunks("/home/u/data.bin").unwrap().1;
    let snap_id = world.home(|s| s.home_mut().snapshot(t(1.0)).unwrap());
    // v2 replaces every byte: v1's chunks lose their residency refs but
    // stay pinned by the snapshot manifest AND the un-shipped records
    let mut v2 = vec![0u8; 64 * 1024];
    rng.fill_bytes(&mut v2);
    c.write_file("/home/u/data.bin", &v2, 65536).unwrap();
    c.fsync().unwrap();
    assert_eq!(world.home(|s| s.home_mut().gc()), (0, 0), "every chunk is pinned");
    // ship by reference: the secondary misses every chunk, asks, gets
    // the push, acks — and the primary truncates the acked prefix
    assert_eq!(world.replica_tick(true), 0, "ref shipping drains");
    assert!(world.metrics.counter(names::REPLICA_CHUNK_PUSHES) >= 1);
    assert!(world.metrics.counter(names::REPLICA_LOG_TRUNCATED) >= 1);
    assert!(world.server.repl_records_after(0, usize::MAX).is_empty());
    let sec = world.secondary().unwrap();
    assert_eq!(sec.home().read("/home/u/data.bin").unwrap(), v2, "materialized at the standby");
    // the log pins are gone; the snapshot alone still protects v1
    assert_eq!(world.home(|s| s.home_mut().gc()).0, 0, "snapshot still pins v1");
    // drop the live file: ONLY v2's now-unreferenced chunk sweeps
    c.unlink("/home/u/data.bin").unwrap();
    c.fsync().unwrap();
    assert_eq!(world.home(|s| s.home_mut().gc()), (1, v2.len() as u64));
    assert!(world.metrics.counter(names::CHUNK_GC_COLLECTED) >= 1);
    let g = world.server.home();
    let cs = g.chunkstore().unwrap();
    for d in &v1_digests {
        assert!(cs.contains(d), "snapshot-pinned chunk survived the sweep");
    }
    assert_eq!(g.read(&format!("/home/u/data.bin@v{snap_id}")).unwrap(), v1);
}

/// Promotion AFTER ref-based shipping and acked-prefix truncation, with
/// the secondary still missing chunks at promote time: the drain inside
/// the promote ships the records numbered past the truncated base,
/// pushes exactly the missing chunk bytes, and the promoted node serves
/// every file byte-identical — to direct reads and to the failed-over
/// client.
#[test]
fn promote_after_truncation_ships_missing_chunks_and_serves() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap());
    world.enable_replica();
    let mut c = world.mount("/home/u").unwrap();
    let mut rng = Rng::new(0x9001);
    let mut big = vec![0u8; 320 * 1024];
    rng.fill_bytes(&mut big);
    c.write_file("/home/u/tool.bin", &big, 65536).unwrap();
    c.write_file("/home/u/note.txt", b"survives failover\n", 1024).unwrap();
    c.fsync().unwrap();
    assert_eq!(world.replica_tick(true), 0);
    let base = world.server.repl_base();
    assert!(base > 0, "acked prefix truncated after the drain");
    let pushes = world.metrics.counter(names::REPLICA_CHUNK_PUSHES);
    assert!(pushes >= 1);
    // more work lands AFTER the truncation, unshipped: its first 64 KiB
    // chunk dedups against tool.bin, its 32 KiB tail is brand new
    c.write_file("/home/u/late.bin", &big[..96 * 1024], 65536).unwrap();
    c.fsync().unwrap();
    assert!(world.server.repl_ship_seq() > base);
    world.server_crash();
    world.promote_secondary().unwrap();
    assert!(world.is_promoted());
    assert!(
        world.metrics.counter(names::REPLICA_CHUNK_PUSHES) > pushes,
        "the promote drain pushed the missing tail chunk"
    );
    let authority = world.authority();
    assert_eq!(authority.home().read("/home/u/tool.bin").unwrap(), big);
    assert_eq!(authority.home().read("/home/u/note.txt").unwrap(), b"survives failover\n");
    assert_eq!(authority.home().read("/home/u/late.bin").unwrap(), &big[..96 * 1024]);
    // and the failed-over client reads through the promoted node
    c.link_mut().reconnect().unwrap();
    assert_eq!(c.link().active_endpoint(), 1);
    let got = read_all(&mut c, "/home/u/late.bin").unwrap();
    assert_eq!(got, &big[..96 * 1024]);
}

// ---------------------------------------------------------------------
// directed read-fanout tests (DESIGN.md §2.11)
// ---------------------------------------------------------------------

/// A config with `n` serving read replicas and shipping only on demand
/// (`max_lag_ops` far above anything a directed test queues).
fn fanout_cfg(n: usize) -> XufsConfig {
    let mut cfg = XufsConfig::default();
    cfg.replica.secondaries = n;
    cfg.replica.read_fanout = true;
    cfg.replica.max_lag_ops = 1000;
    cfg
}

/// Bounded-staleness gate, both halves: a replica lagging behind the
/// client's observed version answers code 119 `TooStale` and the read
/// transparently falls back to the primary (never serving the old
/// bytes); once shipping catches the fleet up, the SAME replica serves
/// the read itself.
#[test]
fn read_replica_answers_too_stale_then_serves_after_catch_up() {
    let mut world = SimWorld::new(fanout_cfg(2));
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/doc", b"v1 in the initial snapshot", t(0.0)).unwrap();
    });
    world.enable_replica();
    let mut a = world.mount("/home/u").unwrap();
    let mut b = world.mount("/home/u").unwrap();
    // a caches v1; b then advances the file past the fleet's watermark
    a.scan_file("/home/u/doc", 1024).unwrap();
    b.write_file("/home/u/doc", b"v2 far ahead of the fleet", 1024).unwrap();
    // the invalidation callback taught a the new version — its
    // bounded-staleness floor. Its next read, pinned at the lagging
    // replica, must surface v2 via the fallback, never v1.
    a.link_mut().set_read_preference(Some(1));
    let stale0 = world.metrics.counter(names::REPLICA_TOO_STALE);
    let redirect0 = world.metrics.counter(names::REPLICA_READ_REDIRECTS);
    assert_eq!(read_all(&mut a, "/home/u/doc").unwrap(), b"v2 far ahead of the fleet");
    assert!(world.metrics.counter(names::REPLICA_TOO_STALE) > stale0, "replica refused as 119");
    assert!(world.metrics.counter(names::REPLICA_READ_REDIRECTS) > redirect0);
    // the fleet catches up; the same replica now serves a fresh session
    assert_eq!(world.replica_tick(true), 0);
    let mut f = world.mount("/home/u").unwrap();
    f.link_mut().set_read_preference(Some(1));
    let hits0 = world.metrics.counter(names::REPLICA_READ_HITS);
    assert_eq!(read_all(&mut f, "/home/u/doc").unwrap(), b"v2 far ahead of the fleet");
    assert!(
        world.metrics.counter(names::REPLICA_READ_HITS) > hits0,
        "the caught-up replica serves the read itself"
    );
}

/// The I6 edge: a session that read from a replica keeps observing
/// non-decreasing versions through a primary-only write (too-stale
/// fallback) AND through a crash + promotion (the promote drain catches
/// the new primary up before it serves).
#[test]
fn reads_never_observe_version_regress_across_promotion() {
    let mut world = SimWorld::new(fanout_cfg(2));
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/doc", b"v1", t(0.0)).unwrap();
    });
    world.enable_replica();
    let mut w = world.mount("/home/u").unwrap();
    let mut r = world.mount("/home/u").unwrap();
    w.write_file("/home/u/doc", b"v2 on the whole fleet", 1024).unwrap();
    assert_eq!(world.replica_tick(true), 0);
    // the reader observes v2 from replica 2...
    r.link_mut().set_read_preference(Some(2));
    assert_eq!(read_all(&mut r, "/home/u/doc").unwrap(), b"v2 on the whole fleet");
    let v2 = r.cache().entry("/home/u/doc").unwrap().version;
    // ...the writer advances the primary past the fleet...
    w.write_file("/home/u/doc", b"v3 only on the primary", 1024).unwrap();
    // ...and the reader, still pinned at the now-lagging replica, must
    // see v3 via the fallback — the version only grows
    assert_eq!(read_all(&mut r, "/home/u/doc").unwrap(), b"v3 only on the primary");
    let v3 = r.cache().entry("/home/u/doc").unwrap().version;
    assert!(v3 > v2, "observed versions grow: v{v2} then v{v3}");
    // the primary dies; the promote drain catches the new primary up to
    // v3 BEFORE it serves, so the failed-over reader never regresses
    world.server_crash();
    world.promote_secondary().unwrap();
    r.link_mut().reconnect().unwrap();
    assert_eq!(r.link().active_endpoint(), 1);
    assert_eq!(read_all(&mut r, "/home/u/doc").unwrap(), b"v3 only on the primary");
    let v_post = r.cache().entry("/home/u/doc").unwrap().version;
    assert!(v_post >= v3, "promotion never rewinds observed versions: v{v3} then v{v_post}");
}

/// A path FIRST created inside the acked-and-truncated repl-log prefix
/// (DESIGN.md §2.8 retention) must still serve from every read replica:
/// the replicas materialized it before the primary dropped the records.
#[test]
fn fanout_read_serves_path_born_inside_truncated_log_prefix() {
    let mut cfg = fanout_cfg(2);
    cfg.replica.max_lag_ops = XufsConfig::default().replica.max_lag_ops;
    let mut world = SimWorld::new(cfg);
    world.home(|s| s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap());
    world.enable_replica();
    let mut c = world.mount("/home/u").unwrap();
    c.write_file("/home/u/born.txt", b"born after the snapshot", 1024).unwrap();
    c.fsync().unwrap();
    assert_eq!(world.replica_tick(true), 0);
    assert!(world.server.repl_base() > 0, "the acked prefix truncated");
    for k in 1..=2usize {
        let mut r = world.mount("/home/u").unwrap();
        r.link_mut().set_read_preference(Some(k));
        let hits0 = world.metrics.counter(names::REPLICA_READ_HITS);
        assert_eq!(read_all(&mut r, "/home/u/born.txt").unwrap(), b"born after the snapshot");
        assert!(
            world.metrics.counter(names::REPLICA_READ_HITS) > hits0,
            "replica {k} serves the truncated-prefix birth"
        );
    }
}

/// Integrity on the read plane (DESIGN.md §2.10 meets §2.11): a rotted
/// chunk on a read replica is REFUSED (code 118 → primary fallback,
/// byte-exact data), healed from the primary's clean copy by the repair
/// tick, and only then served by the replica again.
#[test]
fn rotted_replica_chunk_falls_back_then_heals_then_serves() {
    let mut world = SimWorld::new(fanout_cfg(2));
    world.home(|s| s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap());
    world.enable_replica();
    let mut c = world.mount("/home/u").unwrap();
    let mut big = vec![0u8; 300 * 1024];
    let mut rng = Rng::new(0x2B11);
    rng.fill_bytes(&mut big);
    c.write_file("/home/u/data.bin", &big, 65536).unwrap();
    c.fsync().unwrap();
    assert_eq!(world.replica_tick(true), 0, "chunks shipped to the fleet");
    // rot one byte of one chunk on replica 0 (= endpoint 1)
    world.corrupt_replica_chunk(0, 7).expect("the replica holds chunks");
    let redirect0 = world.metrics.counter(names::REPLICA_READ_REDIRECTS);
    let mut r = world.mount("/home/u").unwrap();
    r.link_mut().set_read_preference(Some(1));
    assert_eq!(read_all(&mut r, "/home/u/data.bin").unwrap(), big, "never rotted bytes");
    assert!(
        world.metrics.counter(names::REPLICA_READ_REDIRECTS) > redirect0,
        "the rotted page was refused by the replica and re-read from the primary"
    );
    // the repair tick scrubs the replica and heals it from the primary
    assert_eq!(world.repair_tick().unwrap(), 0, "quarantine drained");
    assert!(world.secondary().unwrap().quarantined_chunks().is_empty());
    // a fresh session now reads the whole file from the healed replica
    let mut r2 = world.mount("/home/u").unwrap();
    r2.link_mut().set_read_preference(Some(1));
    let hits0 = world.metrics.counter(names::REPLICA_READ_HITS);
    let redirect1 = world.metrics.counter(names::REPLICA_READ_REDIRECTS);
    assert_eq!(read_all(&mut r2, "/home/u/data.bin").unwrap(), big);
    assert!(world.metrics.counter(names::REPLICA_READ_HITS) > hits0);
    assert_eq!(
        world.metrics.counter(names::REPLICA_READ_REDIRECTS),
        redirect1,
        "no fallback needed after the heal"
    );
}
