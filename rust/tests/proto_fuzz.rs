//! Round-trip fuzz for the whole wire protocol (DESIGN.md §2.7
//! satellite): seeded random instances of every message family —
//! requests, responses, meta-ops, notifications, replication records and
//! HMAC-framed replication batches — must
//!
//! * decode back to exactly the value that was encoded,
//! * re-encode byte-identically (the codec is canonical), and
//! * reject EVERY strict prefix of a valid frame with an error — never a
//!   panic, never a silent partial parse (length-prefixed fields plus
//!   `expect_end` make truncations structurally undecodable).
//!
//! Random single-byte corruptions additionally must never panic (they
//! may decode to a different valid message — the transports layer HMACs
//! and length prefixes above this codec).

use xufs::chunkstore::Digest;
use xufs::proto::{
    BlockExtent, CompoundOp, DirEntry, FileImage, FrameDecoder, FrameWriter, LockKind, MetaOp,
    NotifyEvent, ReplPayload, ReplRecord, Request, Response, WireAttr, MAX_FRAME,
};
use xufs::replica::{decode_frames, frame_records};
use xufs::util::Rng;

const CASES: usize = 200;

fn rand_string(rng: &mut Rng) -> String {
    const ALPHA: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789/._-";
    let n = rng.below(16) as usize;
    (0..n).map(|_| ALPHA[rng.below(ALPHA.len() as u64) as usize] as char).collect()
}

fn rand_bytes(rng: &mut Rng, max: u64) -> Vec<u8> {
    let mut v = vec![0u8; rng.below(max + 1) as usize];
    rng.fill_bytes(&mut v);
    v
}

fn rand_digests(rng: &mut Rng) -> Vec<i32> {
    (0..rng.below(6)).map(|_| rng.next_u32() as i32).collect()
}

/// Random content-address digests (DESIGN.md §2.8 chunk references).
fn rand_chunk_digests(rng: &mut Rng) -> Vec<Digest> {
    (0..rng.below(5))
        .map(|_| {
            let mut d = [0u8; 32];
            rng.fill_bytes(&mut d);
            d
        })
        .collect()
}

fn rand_attr(rng: &mut Rng) -> WireAttr {
    WireAttr {
        kind: if rng.chance(0.2) { xufs::homefs::NodeKind::Dir } else { xufs::homefs::NodeKind::File },
        size: rng.next_u64() >> rng.below(40),
        mtime_ns: rng.next_u64() >> rng.below(20),
        mode: rng.next_u32() & 0o7777,
        version: rng.below(1 << 30),
    }
}

fn rand_metaop(rng: &mut Rng) -> MetaOp {
    match rng.below(10) {
        0 => MetaOp::Mkdir { path: rand_string(rng) },
        1 => MetaOp::Rmdir { path: rand_string(rng) },
        2 => MetaOp::Create { path: rand_string(rng) },
        3 => MetaOp::Unlink { path: rand_string(rng) },
        4 => MetaOp::Rename { from: rand_string(rng), to: rand_string(rng) },
        5 => MetaOp::Truncate { path: rand_string(rng), size: rng.next_u64() >> 20 },
        6 => MetaOp::SetMode { path: rand_string(rng), mode: rng.next_u32() & 0o7777 },
        7 => MetaOp::WriteFull {
            path: rand_string(rng),
            data: rand_bytes(rng, 48),
            digests: rand_digests(rng),
            base_version: rng.below(1 << 20),
        },
        8 => MetaOp::WriteDelta {
            path: rand_string(rng),
            total_size: rng.below(1 << 30),
            base_version: rng.below(1 << 20),
            blocks: (0..rng.below(4))
                .map(|i| (i as u32, rand_bytes(rng, 32)))
                .collect(),
            digests: rand_digests(rng),
        },
        _ => MetaOp::WriteRef {
            path: rand_string(rng),
            size: rng.below(1 << 40),
            chunks: rand_chunk_digests(rng),
            digests: rand_digests(rng),
            base_version: rng.below(1 << 20),
        },
    }
}

fn rand_repl_record(rng: &mut Rng) -> ReplRecord {
    let payload = match rng.below(3) {
        0 => ReplPayload::Op {
            client_id: rng.below(64),
            seq: rng.below(1 << 30),
            new_version: rng.below(1 << 30),
            op: rand_metaop(rng),
        },
        1 => ReplPayload::Failed {
            client_id: rng.below(64),
            seq: rng.below(1 << 30),
            path: rand_string(rng),
        },
        _ => ReplPayload::Local { op: rand_metaop(rng) },
    };
    ReplRecord { ship_seq: rng.below(1 << 40) + 1, shard: rng.below(64) as u32, payload }
}

fn rand_request(rng: &mut Rng) -> Request {
    match rng.below(20) {
        0 => Request::AuthHello { key_id: rand_string(rng) },
        1 => Request::AuthProof { key_id: rand_string(rng), proof: rand_bytes(rng, 48) },
        2 => Request::Stat { path: rand_string(rng) },
        3 => Request::ReadDir { path: rand_string(rng) },
        4 => Request::Fetch { path: rand_string(rng), min_version: rng.below(1 << 30) },
        5 => Request::FetchMeta { path: rand_string(rng), min_version: rng.below(1 << 30) },
        6 => Request::FetchRange {
            path: rand_string(rng),
            offset: rng.next_u64() >> 20,
            len: rng.below(1 << 30),
            expect_version: rng.below(1 << 30),
        },
        7 => Request::Apply { seq: rng.below(1 << 30), op: rand_metaop(rng) },
        8 => Request::RegisterCallback { root: rand_string(rng), client_id: rng.below(64) },
        9 => Request::LockAcquire {
            path: rand_string(rng),
            kind: if rng.chance(0.5) { LockKind::Shared } else { LockKind::Exclusive },
            owner: rng.below(64),
        },
        10 => Request::LockRenew { token: rng.next_u64(), owner: rng.below(64) },
        11 => Request::LockRelease { token: rng.next_u64(), owner: rng.below(64) },
        12 => Request::Ping,
        13 => Request::Compound {
            ops: (0..rng.below(4))
                .map(|_| {
                    if rng.chance(0.3) {
                        CompoundOp::Stat { path: rand_string(rng) }
                    } else {
                        CompoundOp::Apply { seq: rng.below(1 << 30), op: rand_metaop(rng) }
                    }
                })
                .collect(),
        },
        14 => Request::Replicate {
            from: rng.below(1 << 40),
            frames: rand_bytes(rng, 64),
            head: rng.below(1 << 40),
        },
        15 => Request::WatermarkQuery { shard: rng.next_u32() },
        16 => Request::Promote,
        17 => Request::ChunkPush {
            chunks: (0..rng.below(4)).map(|_| rand_bytes(rng, 48)).collect(),
        },
        18 => Request::SnapshotCreate,
        _ => Request::ChunkFetch { digests: rand_chunk_digests(rng) },
    }
}

fn rand_response(rng: &mut Rng, nested: bool) -> Response {
    // CompoundReply never nests (the codec rejects it); the generator
    // respects that so every generated frame is valid
    let top = if nested { 22 } else { 23 };
    match rng.below(top) {
        0 => Response::Challenge { nonce: rand_bytes(rng, 32) },
        1 => Response::AuthOk { session: rng.next_u64() },
        2 => Response::AuthFail,
        3 => Response::Attr { attr: rand_attr(rng) },
        4 => Response::Dir {
            entries: (0..rng.below(4))
                .map(|_| DirEntry { name: rand_string(rng), attr: rand_attr(rng) })
                .collect(),
        },
        5 => Response::File {
            image: FileImage {
                path: rand_string(rng),
                version: rng.below(1 << 30),
                data: rand_bytes(rng, 48),
                digests: rand_digests(rng),
            },
        },
        6 => Response::Applied { seq: rng.below(1 << 30), new_version: rng.below(1 << 30) },
        7 => Response::CallbackRegistered,
        8 => Response::LockGranted { token: rng.next_u64(), lease_ns: rng.next_u64() >> 10 },
        9 => Response::LockDenied { holder: rng.below(64) },
        10 => Response::Released,
        11 => Response::Pong,
        12 => Response::Err { code: rng.next_u32() & 0xFFFF, msg: rand_string(rng) },
        13 => Response::FileMeta {
            version: rng.below(1 << 30),
            size: rng.below(1 << 40),
            digests: rand_digests(rng),
        },
        14 => Response::FileBlocks {
            version: rng.below(1 << 30),
            extents: (0..rng.below(4))
                .map(|i| BlockExtent {
                    index: i as u32,
                    data: rand_bytes(rng, 48),
                    digest: rng.next_u32() as i32,
                })
                .collect(),
        },
        15 => Response::ReplicaAck { watermark: rng.below(1 << 40) },
        16 => Response::Watermark { shard: rng.next_u32(), watermark: rng.below(1 << 40) },
        17 => Response::Promoted { watermark: rng.below(1 << 40) },
        18 => Response::ReplicaNeed { digests: rand_chunk_digests(rng) },
        19 => Response::ChunkAck { stored: rng.below(1 << 40) },
        20 => Response::SnapshotCreated { id: rng.below(1 << 40) },
        21 => Response::ChunkFill {
            chunks: (0..rng.below(4)).map(|_| rand_bytes(rng, 48)).collect(),
        },
        _ => Response::CompoundReply {
            replies: (0..rng.below(4)).map(|_| rand_response(rng, true)).collect(),
        },
    }
}

fn rand_notify(rng: &mut Rng) -> NotifyEvent {
    match rng.below(3) {
        0 => NotifyEvent::Invalidate { path: rand_string(rng), new_version: rng.below(1 << 30) },
        1 => NotifyEvent::Removed { path: rand_string(rng) },
        _ => NotifyEvent::ServerRestart,
    }
}

/// Shared property: canonical roundtrip + every strict prefix rejected.
fn assert_frame_properties<T, E, D>(value: &T, bytes: &[u8], decode: D)
where
    T: PartialEq + std::fmt::Debug,
    E: std::fmt::Debug,
    D: Fn(&[u8]) -> Result<T, E>,
{
    let back = decode(bytes).unwrap_or_else(|e| panic!("decode of {value:?} failed: {e:?}"));
    assert_eq!(&back, value, "decode(encode(x)) != x");
    for cut in 0..bytes.len() {
        assert!(
            decode(&bytes[..cut]).is_err(),
            "{}-byte prefix of {value:?} decoded successfully",
            cut
        );
    }
}

#[test]
fn requests_roundtrip_and_reject_truncation() {
    let mut rng = Rng::new(0xF422_0001);
    for _ in 0..CASES {
        let r = rand_request(&mut rng);
        let b = r.encode();
        assert_frame_properties(&r, &b, Request::decode);
        assert_eq!(Request::decode(&b).unwrap().encode(), b, "re-encode must be byte-identical");
    }
}

#[test]
fn responses_roundtrip_and_reject_truncation() {
    let mut rng = Rng::new(0xF422_0002);
    for _ in 0..CASES {
        let r = rand_response(&mut rng, false);
        let b = r.encode();
        assert_frame_properties(&r, &b, Response::decode);
        assert_eq!(Response::decode(&b).unwrap().encode(), b, "re-encode must be byte-identical");
    }
}

#[test]
fn metaops_roundtrip_and_reject_truncation() {
    let mut rng = Rng::new(0xF422_0003);
    for _ in 0..CASES {
        let op = rand_metaop(&mut rng);
        let b = op.encode();
        assert_frame_properties(&op, &b, MetaOp::decode);
        assert_eq!(MetaOp::decode(&b).unwrap().encode(), b);
    }
}

#[test]
fn notifications_roundtrip_and_reject_truncation() {
    let mut rng = Rng::new(0xF422_0004);
    for _ in 0..CASES {
        let ev = rand_notify(&mut rng);
        let b = ev.encode();
        assert_frame_properties(&ev, &b, NotifyEvent::decode);
        assert_eq!(NotifyEvent::decode(&b).unwrap().encode(), b);
    }
}

#[test]
fn repl_records_roundtrip_and_reject_truncation() {
    let mut rng = Rng::new(0xF422_0005);
    for _ in 0..CASES {
        let rec = rand_repl_record(&mut rng);
        let b = rec.encode();
        assert_frame_properties(&rec, &b, ReplRecord::decode);
        assert_eq!(ReplRecord::decode(&b).unwrap().encode(), b);
    }
}

#[test]
fn replication_batches_roundtrip_and_reject_tampering() {
    let mut rng = Rng::new(0xF422_0006);
    for _ in 0..40 {
        let records: Vec<ReplRecord> =
            (0..rng.below(5) + 1).map(|_| rand_repl_record(&mut rng)).collect();
        let buf = frame_records(&records);
        assert_eq!(decode_frames(&buf).unwrap(), records);
        // a cut exactly between frames is a valid SHORTER batch (how a
        // reply-loss re-send stays safe); any other prefix is torn and
        // the WHOLE batch is refused — never a panic, never a partial
        // accept
        let mut boundaries = vec![0usize];
        for r in &records {
            // frame = len:u32 | record | hmac:32
            let len = 4 + r.encode().len() + 32;
            boundaries.push(boundaries.last().unwrap() + len);
        }
        for cut in 1..buf.len() {
            match decode_frames(&buf[..cut]) {
                Ok(got) => {
                    let k = boundaries
                        .iter()
                        .position(|b| *b == cut)
                        .unwrap_or_else(|| panic!("non-boundary prefix of {cut} bytes accepted"));
                    assert_eq!(got, records[..k], "boundary cut {cut}");
                }
                Err(_) => assert!(
                    !boundaries.contains(&cut),
                    "boundary cut {cut} must decode to a record prefix"
                ),
            }
        }
        // one flipped byte anywhere breaks a frame's HMAC (or its
        // framing) — refused, never panicking, never partially applied
        let mut bad = buf.clone();
        let at = rng.below(bad.len() as u64) as usize;
        bad[at] ^= 0x01;
        assert!(decode_frames(&bad).is_err(), "flip at {at} accepted");
    }
}

#[test]
fn random_corruptions_never_panic() {
    let mut rng = Rng::new(0xF422_0007);
    for _ in 0..CASES {
        let mut b = rand_request(&mut rng).encode();
        let at = rng.below(b.len() as u64) as usize;
        b[at] ^= (rng.below(255) + 1) as u8;
        // a corrupted frame may decode to a DIFFERENT valid message
        // (transports add HMACs above this layer) — but it must never
        // panic, and whatever decodes must re-encode canonically
        if let Ok(r) = Request::decode(&b) {
            assert_eq!(Request::decode(&r.encode()).unwrap(), r);
        }
        let mut b = rand_response(&mut rng, false).encode();
        let at = rng.below(b.len() as u64) as usize;
        b[at] ^= (rng.below(255) + 1) as u8;
        if let Ok(r) = Response::decode(&b) {
            assert_eq!(Response::decode(&r.encode()).unwrap(), r);
        }
        let mut b = rand_metaop(&mut rng).encode();
        let at = rng.below(b.len() as u64) as usize;
        b[at] ^= (rng.below(255) + 1) as u8;
        if let Ok(op) = MetaOp::decode(&b) {
            assert_eq!(MetaOp::decode(&op.encode()).unwrap(), op);
        }
    }
}

/// The §2.9 streaming decoder must be arrival-pattern-independent: a
/// frame sequence delivered in arbitrary seeded splits (1–7-byte pieces,
/// the worst case a WAN path can produce) decodes to exactly the frames a
/// one-shot delivery would, with every frame byte-identical.
#[test]
fn streaming_decoder_chunked_arrival_equals_one_shot() {
    let mut rng = Rng::new(0xF422_0009);
    for _ in 0..CASES {
        let msgs: Vec<Request> = (0..rng.below(12) + 1).map(|_| rand_request(&mut rng)).collect();
        // the sender side: every frame encoded through the reused writer
        // buffer, drained into one contiguous byte stream
        let mut w = FrameWriter::new();
        let mut stream: Vec<u8> = Vec::new();
        for m in &msgs {
            w.frame(|e| m.encode_into(e));
        }
        assert!(w.flush_to(&mut stream).unwrap(), "Vec sink must drain fully");
        // the receiver side: the same stream pushed in random small pieces
        let mut dec = FrameDecoder::new(MAX_FRAME);
        let mut got: Vec<Request> = Vec::new();
        let mut at = 0usize;
        while at < stream.len() {
            let n = (rng.below(7) + 1) as usize;
            let end = (at + n).min(stream.len());
            dec.push(&stream[at..end]);
            at = end;
            while let Some(frame) = dec.next_frame().expect("chunked arrival broke framing") {
                got.push(Request::decode(frame).expect("frame bytes differ from one-shot"));
            }
        }
        assert_eq!(got, msgs, "chunked arrival decoded a different sequence");
        assert_eq!(dec.buffered(), 0, "stream fully consumed");
    }
}

/// Torn and tampered streams must never panic the streaming decoder: a
/// truncated stream yields exactly the complete frames before the tear
/// then waits for more bytes; a flipped byte either surfaces as a decode
/// error (a length prefix above the cap, a payload that fails
/// `Request::decode`) or decodes to a different valid message — the
/// reactor maps the error to a typed code-71 reply, it never crashes.
#[test]
fn streaming_decoder_torn_and_tampered_never_panic() {
    let mut rng = Rng::new(0xF422_000A);
    for _ in 0..CASES {
        let msgs: Vec<Request> = (0..rng.below(6) + 1).map(|_| rand_request(&mut rng)).collect();
        let mut w = FrameWriter::new();
        let mut stream: Vec<u8> = Vec::new();
        for m in &msgs {
            w.frame(|e| m.encode_into(e));
        }
        assert!(w.flush_to(&mut stream).unwrap());
        // torn: any strict prefix yields only whole frames, then None
        let cut = rng.below(stream.len() as u64) as usize;
        let mut dec = FrameDecoder::new(MAX_FRAME);
        dec.push(&stream[..cut]);
        let mut whole = 0usize;
        while let Some(frame) = dec.next_frame().expect("a torn stream is not a framing error") {
            Request::decode(frame).expect("complete frames before the tear stay intact");
            whole += 1;
        }
        assert!(whole <= msgs.len());
        // tampered: one flipped byte anywhere — errors allowed, panics not
        let mut bad = stream.clone();
        let at = rng.below(bad.len() as u64) as usize;
        bad[at] ^= (rng.below(255) + 1) as u8;
        let mut dec = FrameDecoder::new(MAX_FRAME);
        dec.push(&bad);
        loop {
            match dec.next_frame() {
                Ok(Some(frame)) => {
                    // may or may not decode; must not panic, and whatever
                    // decodes re-encodes canonically
                    if let Ok(r) = Request::decode(frame) {
                        assert_eq!(Request::decode(&r.encode()).unwrap(), r);
                    }
                }
                Ok(None) => break,
                Err(_) => break, // oversized length prefix: framing lost, refused
            }
        }
    }
}

/// Compressed `WriteDelta` frames (DESIGN.md §2.12 delta-compressed
/// writebacks): the in-place compressor must leave a fully canonical
/// wire frame — roundtrip, strict-prefix rejection and re-encode all
/// hold on the COMPRESSED form — and `decode_block` must recover the
/// exact pre-compression bytes through the self-describing flag bit.
/// Single-byte flips (the flag byte included) may be refused or decode
/// to a different valid frame, but must never panic.
#[test]
fn compressed_write_deltas_roundtrip_decode_and_never_panic() {
    use xufs::metrics::Metrics;
    use xufs::transfer::compress::{compress_delta_op, decode_block};

    let mut rng = Rng::new(0xF422_000B);
    let metrics = Metrics::new();
    for _ in 0..CASES {
        // block shapes biased towards compressible payloads so the
        // framed path is actually exercised (pure-random never shrinks);
        // runs hit the RLE arm, repeated units the LZ arm
        let blocks: Vec<(u32, Vec<u8>)> = (0..rng.below(4) + 1)
            .map(|i| {
                let data = match rng.below(3) {
                    0 => vec![rng.below(256) as u8; (rng.below(64) + 8) as usize],
                    1 => {
                        let mut unit = rand_bytes(&mut rng, 6);
                        unit.push(rng.below(256) as u8);
                        let mut v = Vec::new();
                        while v.len() < 48 {
                            v.extend_from_slice(&unit);
                        }
                        v
                    }
                    _ => rand_bytes(&mut rng, 48),
                };
                (i as u32, data)
            })
            .collect();
        let originals = blocks.clone();
        let mut op = MetaOp::WriteDelta {
            path: rand_string(&mut rng),
            total_size: rng.below(1 << 30),
            base_version: rng.below(1 << 20),
            blocks,
            digests: rand_digests(&mut rng),
        };
        compress_delta_op(&mut op, &metrics);
        let b = op.encode();
        assert_frame_properties(&op, &b, MetaOp::decode);
        assert_eq!(MetaOp::decode(&b).unwrap().encode(), b, "re-encode must be byte-identical");
        // every block — legacy raw and flag-bit framed alike — decodes
        // back to exactly the pre-compression index and bytes
        let MetaOp::WriteDelta { blocks, .. } = &op else { unreachable!() };
        for ((idx, payload), (oidx, odata)) in blocks.iter().zip(&originals) {
            let (di, dd) =
                decode_block(*idx, payload, 1 << 20).expect("self-framed block decodes");
            assert_eq!(di, *oidx, "flag bit must strip back to the plain index");
            assert_eq!(dd.as_ref(), &odata[..], "decoded bytes differ from pre-compression");
        }
        // tampered: one flipped byte anywhere in the wire frame
        let mut bad = b.clone();
        let at = rng.below(bad.len() as u64) as usize;
        bad[at] ^= (rng.below(255) + 1) as u8;
        if let Ok(back) = MetaOp::decode(&bad) {
            assert_eq!(MetaOp::decode(&back.encode()).unwrap(), back);
            if let MetaOp::WriteDelta { blocks, .. } = &back {
                for (idx, payload) in blocks {
                    // may refuse (None) — must never panic
                    let _ = decode_block(*idx, payload, 1 << 20);
                }
            }
        }
    }
}

/// Directed corruption of the §2.8 chunk-reference blob: a `WriteRef`
/// whose digest blob is not a whole number of 32-byte digests must be
/// REJECTED (never panic, never round down), and single-byte flips
/// anywhere in a `WriteRef`/`ReplicaNeed` frame must stay panic-free.
#[test]
fn chunk_digest_blob_corruptions_rejected_never_panic() {
    let mut rng = Rng::new(0xF422_0008);
    for _ in 0..CASES {
        let op = MetaOp::WriteRef {
            path: rand_string(&mut rng),
            size: rng.below(1 << 40),
            chunks: rand_chunk_digests(&mut rng),
            digests: rand_digests(&mut rng),
            base_version: rng.below(1 << 20),
        };
        let b = op.encode();
        // every strict prefix tears the blob or the trailing fields
        assert_frame_properties(&op, &b, MetaOp::decode);
        // arbitrary flips: reject or decode-to-valid, never panic
        let mut bad = b.clone();
        let at = rng.below(bad.len() as u64) as usize;
        bad[at] ^= (rng.below(255) + 1) as u8;
        if let Ok(back) = MetaOp::decode(&bad) {
            assert_eq!(MetaOp::decode(&back.encode()).unwrap(), back);
        }
        let need = Response::ReplicaNeed { digests: rand_chunk_digests(&mut rng) };
        let mut nb = need.encode();
        let at = rng.below(nb.len() as u64) as usize;
        nb[at] ^= (rng.below(255) + 1) as u8;
        if let Ok(back) = Response::decode(&nb) {
            assert_eq!(Response::decode(&back.encode()).unwrap(), back);
        }
    }
}
