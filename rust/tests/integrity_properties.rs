//! End-to-end integrity plane (DESIGN.md §2.10): seeded bit-rot fuzz
//! and directed repair tests across every durable artifact — the
//! server's content-addressed chunk store, dense home files, client
//! cache disks, and the durable op log. The contract under test is
//! invariant I5: rot is always DETECTED (quarantine + repair, block
//! demotion, dropped record, or a typed `FsError::Corrupted` refusal)
//! and never served as data, never a panic.

use xufs::client::{OpenFlags, ServerLink, Vfs, WritebackMode, XufsClient};
use xufs::config::XufsConfig;
use xufs::coordinator::{SimLink, SimWorld};
use xufs::homefs::FsError;
use xufs::metaq::OPLOG_PATH;
use xufs::metrics::names;
use xufs::simnet::VirtualTime;
use xufs::util::Rng;

fn t(s: f64) -> VirtualTime {
    VirtualTime::from_secs(s)
}

fn read_all(c: &mut XufsClient<SimLink>, path: &str) -> Result<Vec<u8>, FsError> {
    let fd = c.open(path, OpenFlags::rdonly())?;
    let mut out = Vec::new();
    let mut buf = vec![0u8; 8192];
    loop {
        match c.read(fd, &mut buf) {
            Ok(0) => break,
            Ok(n) => out.extend_from_slice(&buf[..n]),
            Err(e) => {
                let _ = c.close(fd);
                return Err(e);
            }
        }
    }
    c.close(fd)?;
    Ok(out)
}

/// Seeded fuzz over the chunk store: a flipped byte anywhere in the
/// table is refused by every read that touches it (pristine bytes or a
/// typed `Corrupted` — never rotted data), the scrub quarantines
/// exactly the rotted chunk, a fill that fails its digest is rejected,
/// and the pristine fill heals it back to byte-exact service.
#[test]
fn chunk_bitflip_fuzz_detected_never_served_and_repairable() {
    for seed in 0..20u64 {
        let mut world = SimWorld::new(XufsConfig::default());
        let mut rng = Rng::new(0x0B17_F11F ^ seed);
        world.home(|s| s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap());
        let mut files: Vec<(String, Vec<u8>)> = Vec::new();
        for i in 0..3usize {
            let mut data = vec![0u8; 100_000 + 30_000 * i];
            rng.fill_bytes(&mut data);
            let path = format!("/home/u/f{i}");
            world.home(|s| s.home_mut().write(&path, &data, t(0.0)).unwrap());
            files.push((path, data));
        }
        // capture pristine chunk bytes up front (the repair fills below)
        let pristine: Vec<Vec<u8>> = world.home(|s| {
            let g = s.home();
            g.chunk_digests().iter().map(|d| g.chunk_data(d).unwrap()).collect()
        });
        let d = world
            .home(|s| s.home_mut().corrupt_chunk_byte(rng.next_u64()))
            .expect("a stored chunk to rot");
        let mut refused = 0;
        for (path, want) in &files {
            match world.home(|s| s.home().read(path)) {
                Ok(got) => assert_eq!(&got, want, "seed {seed}: {path} served wrong bytes"),
                Err(FsError::Corrupted(_)) => refused += 1,
                Err(e) => panic!("seed {seed}: {path}: unexpected error {e}"),
            }
        }
        assert_eq!(refused, 1, "seed {seed}: exactly one file holds the rotted chunk");
        // the scrub quarantines exactly the rotted chunk
        let bad = world.server.scrub_all_chunks();
        assert_eq!(bad, vec![d], "seed {seed}");
        assert_eq!(world.server.quarantined_chunks(), vec![d], "seed {seed}");
        assert!(world.metrics.counter(names::CHUNK_SCRUB_ERRORS) >= 1);
        // a forged fill is dropped on its own digest check...
        assert_eq!(world.server.repair_chunks(&[b"not the chunk".to_vec()]), 0, "seed {seed}");
        assert_eq!(world.server.quarantined_chunks(), vec![d], "seed {seed}");
        // ...the pristine fill heals (only the quarantined digest takes)
        assert_eq!(world.server.repair_chunks(&pristine), 1, "seed {seed}");
        assert!(world.server.quarantined_chunks().is_empty(), "seed {seed}");
        assert!(world.metrics.counter(names::CHUNK_REPAIRED) >= 1);
        for (path, want) in &files {
            let got = world.home(|s| s.home().read(path).unwrap());
            assert_eq!(&got, want, "seed {seed}: {path} after repair");
        }
    }
}

/// The directed repair-from-replica acceptance case: the primary
/// detects a rotted chunk, quarantines it, fetches the digest-verified
/// bytes from the secondary over `ChunkFetch`/`ChunkFill`, re-verifies,
/// re-pins, and serves — pristine end to end, surfaced in metrics.
#[test]
fn primary_repairs_rotted_chunk_from_secondary() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap());
    world.enable_replica();
    let mut c = world.mount("/home/u").unwrap();
    let mut data = vec![0u8; 256 * 1024];
    let mut rng = Rng::new(0x4EA1_12E5);
    rng.fill_bytes(&mut data);
    c.write_file("/home/u/tool.bin", &data, 65536).unwrap();
    c.fsync().unwrap();
    assert_eq!(world.replica_tick(true), 0, "chunks shipped to the standby");
    // rot the primary's copy of a chunk the secondary also holds
    world.corrupt_shared_chunk(0xC0FF_EE00_0000_0002).expect("a shared chunk exists");
    // the primary refuses the file rather than serving rot...
    assert!(matches!(
        world.home(|s| s.home().read("/home/u/tool.bin")),
        Err(FsError::Corrupted(_))
    ));
    // ...until the repair plane heals it from the secondary
    assert_eq!(world.repair_tick().unwrap(), 0, "every quarantined chunk healed");
    assert!(world.server.quarantined_chunks().is_empty());
    assert!(world.metrics.counter(names::CHUNK_SCRUB_ERRORS) >= 1, "detection surfaced");
    assert!(world.metrics.counter(names::CHUNK_REPAIRED) >= 1, "repair surfaced");
    assert_eq!(world.home(|s| s.home().read("/home/u/tool.bin").unwrap()), data);
    // a fresh client faults the file through the healed primary
    let mut c2 = world.mount("/home/u").unwrap();
    assert_eq!(read_all(&mut c2, "/home/u/tool.bin").unwrap(), data);
}

/// The background scrub rides the op cadence exactly like deferred GC:
/// request traffic alone walks the chunk table and quarantines rot,
/// with the ticks surfaced in metrics.
#[test]
fn background_scrub_rides_op_cadence_and_quarantines_rot() {
    let mut cfg = XufsConfig::default();
    cfg.integrity.scrub_interval_ops = 8;
    cfg.integrity.scrub_batch = 1024;
    let mut world = SimWorld::new(cfg);
    world.home(|s| s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap());
    let mut c = world.mount("/home/u").unwrap();
    let mut data = vec![0u8; 128 * 1024];
    let mut rng = Rng::new(0x5C0B_0005);
    rng.fill_bytes(&mut data);
    c.write_file("/home/u/a.bin", &data, 65536).unwrap();
    c.fsync().unwrap();
    world.home(|s| assert!(s.home_mut().corrupt_chunk_byte(3).is_some()));
    assert!(world.server.quarantined_chunks().is_empty(), "rot is silent until scrubbed");
    // ordinary op traffic drives the deferred scrub
    for i in 0..24 {
        c.write_file(&format!("/home/u/t{i}"), b"tick", 1024).unwrap();
        c.fsync().unwrap();
    }
    assert!(world.metrics.counter(names::INTEGRITY_SCRUB_TICKS) >= 1);
    assert!(world.metrics.counter(names::CHUNK_SCRUB_ERRORS) >= 1);
    assert!(!world.server.quarantined_chunks().is_empty(), "the scrub found the rot");
}

/// Cache-disk rot while a client is down: recovery's verify pass
/// demotes exactly the rotted block to Absent (counted), and the next
/// read re-faults pristine bytes from home instead of serving rot.
#[test]
fn cache_rot_demotes_on_recover_and_refaults_from_home() {
    let mut world = SimWorld::new(XufsConfig::default());
    let mut data = vec![0u8; 200 * 1024];
    let mut rng = Rng::new(0xCAC4_E007);
    rng.fill_bytes(&mut data);
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/big.bin", &data, t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.scan_file("/home/u/big.bin", 65536).unwrap();
    let id = c.link().client_id();
    let mut snap = c.cache_store_snapshot();
    drop(c);
    // rot one byte of the cached content while the process is down
    assert!(snap.corrupt_file_byte("/home/u/big.bin", 77_777));
    let before = world.metrics.counter(names::CACHE_RECOVER_DEMOTED);
    let (mut c2, corrupt) = world.mount_recovered("/home/u", &snap, id).unwrap();
    assert_eq!(corrupt, 0, "the op log itself is intact");
    assert!(
        world.metrics.counter(names::CACHE_RECOVER_DEMOTED) > before,
        "the rotted block demoted instead of surviving recovery"
    );
    assert_eq!(read_all(&mut c2, "/home/u/big.bin").unwrap(), data, "re-faulted, not served");
}

/// Seeded fuzz over the durable op log: a flipped byte anywhere in the
/// log is caught by the per-record HMAC — the damaged suffix is dropped
/// and counted, recovery replays what survived, and nothing wrong ever
/// reaches the home space. Never a panic.
#[test]
fn oplog_bitflip_fuzz_drops_records_and_counts_them() {
    for seed in 0..10u64 {
        let mut world = SimWorld::new(XufsConfig::default());
        world.home(|s| s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap());
        let mut c = world.mount("/home/u").unwrap();
        c.writeback = WritebackMode::Async;
        c.async_flush_threshold = usize::MAX;
        let mut rng = Rng::new(0x106_0106 ^ seed);
        let mut datas: Vec<Vec<u8>> = Vec::new();
        for i in 0..4usize {
            let mut d = vec![0u8; 2048];
            rng.fill_bytes(&mut d);
            c.write_file(&format!("/home/u/q{i}"), &d, 1024).unwrap();
            datas.push(d);
        }
        assert!(c.queue_len() > 0, "seed {seed}: the durable log is non-empty");
        let id = c.link().client_id();
        let mut snap = c.cache_store_snapshot();
        drop(c);
        assert!(snap.corrupt_file_byte(OPLOG_PATH, rng.next_u64()), "seed {seed}");
        let before = world.metrics.counter(names::METAQ_CORRUPT_RECORDS);
        let (c2, corrupt) = world.mount_recovered("/home/u", &snap, id).unwrap();
        assert!(corrupt >= 1, "seed {seed}: the flip is detected, not replayed");
        assert_eq!(
            world.metrics.counter(names::METAQ_CORRUPT_RECORDS) - before,
            corrupt as u64,
            "seed {seed}: detections surface in metrics"
        );
        assert_eq!(c2.queue_len(), 0, "seed {seed}: the surviving prefix replays and drains");
        // dropped ops are LOST, never resurrected wrong: whatever did
        // reach home is byte-exact
        for (i, want) in datas.iter().enumerate() {
            let p = format!("/home/u/q{i}");
            world.home(|s| {
                if s.home().exists(&p) {
                    assert_eq!(&s.home().read(&p).unwrap(), want, "seed {seed}: {p}");
                }
            });
        }
    }
}

/// Dense-substrate rot (the chunkstore ablation): the whole-file sum
/// recorded at write time refuses a rotted read with the typed error,
/// and the refusal travels the wire to the client as `Corrupted` — the
/// client never receives the rotted bytes.
#[test]
fn dense_file_rot_refuses_with_typed_error_end_to_end() {
    let mut cfg = XufsConfig::default();
    cfg.chunkstore.enabled = false;
    let mut world = SimWorld::new(cfg);
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut()
            .write("/home/u/doc", b"dense bytes guarded by a whole-file sum", t(0.0))
            .unwrap();
    });
    assert!(world.home(|s| s.home_mut().corrupt_dense_byte(7)).is_some());
    assert!(matches!(
        world.home(|s| s.home().read("/home/u/doc")),
        Err(FsError::Corrupted(_))
    ));
    let mut c = world.mount("/home/u").unwrap();
    match read_all(&mut c, "/home/u/doc") {
        Err(FsError::Corrupted(_)) => {}
        r => panic!("client must see the typed integrity refusal, got {r:?}"),
    }
}
