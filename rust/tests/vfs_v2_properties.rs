//! Vfs v2 contract tests: buffer-based positional I/O must be equivalent
//! to the sequential defaults on every implementor, open-time flag
//! validation must reject nonsense up front, the whole-file convenience
//! defaults must close their fd on every path, and the compound-RPC queue
//! flush must ship K queued meta-ops in exactly ONE WAN round trip with
//! per-op status (metrics-asserted).

use std::sync::Arc;

use xufs::baselines::LocalFs;
use xufs::client::{Fd, MetaBatchOp, MetaResult, OpenFlags, ServerLink, Vfs, WritebackMode};
use xufs::config::XufsConfig;
use xufs::coordinator::SimWorld;
use xufs::homefs::{FileStore, FsError};
use xufs::metrics::names;
use xufs::proto::{LockKind, WireAttr};
use xufs::simnet::{SimClock, VirtualTime};
use xufs::util::{prop, Rng};
use xufs::vdisk::DiskModel;
use xufs::{prop_assert, prop_assert_eq};

fn t(s: f64) -> VirtualTime {
    VirtualTime::from_secs(s)
}

fn local() -> LocalFs {
    LocalFs::new(FileStore::default(), DiskModel::new(400.0e6, 0.002), Arc::new(SimClock::new()))
}

fn world_with_home() -> SimWorld {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
    });
    world
}

/// The core v2 property: an interleaving of sequential writes (cursor)
/// and positional writes (explicit offsets, incl. hole-punching past
/// EOF) must leave the file byte-identical to a flat `Vec<u8>` model,
/// positional reads must match model slices without moving the cursor,
/// and a sequential scan must reproduce the model exactly.
fn positional_matches_sequential<V: Vfs>(
    vfs: &mut V,
    path: &str,
    rng: &mut Rng,
    size: usize,
) -> Result<(), String> {
    let e = |e: FsError| e.to_string();
    let mut model: Vec<u8> = Vec::new();
    let fd = vfs.open(path, OpenFlags::wronly_create()).map_err(e)?;
    let mut cursor = 0u64;
    for _ in 0..(2 + size / 8) {
        let mut chunk = vec![0u8; rng.range(1, 2048) as usize];
        rng.fill_bytes(&mut chunk);
        if rng.chance(0.5) {
            // sequential write at the cursor
            vfs.write(fd, &chunk).map_err(e)?;
            let end = cursor as usize + chunk.len();
            if model.len() < end {
                model.resize(end, 0);
            }
            model[cursor as usize..end].copy_from_slice(&chunk);
            cursor += chunk.len() as u64;
            prop_assert_eq!(vfs.tell(fd).map_err(e)?, cursor);
        } else {
            // positional write, possibly past EOF (zero-filled hole)
            let off = rng.below(model.len() as u64 + 1024);
            vfs.pwrite(fd, &chunk, off).map_err(e)?;
            let end = off as usize + chunk.len();
            if model.len() < end {
                model.resize(end, 0);
            }
            model[off as usize..end].copy_from_slice(&chunk);
            // pwrite must not move the cursor
            prop_assert_eq!(vfs.tell(fd).map_err(e)?, cursor);
        }
        if rng.chance(0.2) {
            cursor = rng.below(model.len() as u64 + 1);
            vfs.seek(fd, cursor).map_err(e)?;
        }
    }
    vfs.close(fd).map_err(e)?;

    let fd = vfs.open(path, OpenFlags::rdonly()).map_err(e)?;
    for _ in 0..8 {
        let off = rng.below(model.len() as u64 + 64);
        let want_len = rng.range(1, 4096) as usize;
        let mut buf = vec![0u8; want_len];
        let n = vfs.pread(fd, &mut buf, off).map_err(e)?;
        let expect: &[u8] = if (off as usize) < model.len() {
            &model[off as usize..(off as usize + want_len).min(model.len())]
        } else {
            &[]
        };
        prop_assert_eq!(n, expect.len());
        prop_assert!(&buf[..n] == expect, "pread mismatch at {off}");
        // pread must not move the cursor
        prop_assert_eq!(vfs.tell(fd).map_err(e)?, 0);
    }
    let mut got = Vec::new();
    let mut buf = vec![0u8; 1000];
    loop {
        let n = vfs.read(fd, &mut buf).map_err(e)?;
        if n == 0 {
            break;
        }
        got.extend_from_slice(&buf[..n]);
    }
    prop_assert_eq!(got.len(), model.len());
    prop_assert!(got == model, "sequential scan does not match the model");
    vfs.close(fd).map_err(e)?;
    Ok(())
}

#[test]
fn prop_positional_equals_sequential_localfs() {
    prop::check(60, |rng, size| {
        let mut l = local();
        positional_matches_sequential(&mut l, "/w/prop.dat", rng, size)
    });
}

#[test]
fn prop_positional_equals_sequential_xufs() {
    prop::check(25, |rng, size| {
        let mut world = world_with_home();
        let mut c = world.mount("/home/u").map_err(|e| e.to_string())?;
        positional_matches_sequential(&mut c, "/home/u/prop.dat", rng, size)?;
        // the aggregated close flushed home: the home copy must equal the
        // cache copy (write-backs survive the positional path)
        let cache_len = c.stat("/home/u/prop.dat").map_err(|e| e.to_string())?.size;
        let home = world.home(|s| s.home().read("/home/u/prop.dat").unwrap().to_vec());
        prop_assert_eq!(home.len() as u64, cache_len);
        Ok(())
    });
}

/// Block-granular data plane (DESIGN.md §2.4): paged reads must be
/// byte-identical to the whole-file path. Random positional reads on a
/// demand-paged client, a full sequential scan afterwards, and a
/// whole-file-mode (`paging = false`) client must all reproduce the home
/// content exactly, whatever block/readahead geometry the faults hit.
#[test]
fn prop_paged_pread_equals_whole_file_scan() {
    prop::check(15, |rng, size| {
        let mut cfg = XufsConfig::default();
        // shrink the readahead so multi-fault patterns actually happen
        cfg.cache.readahead_blocks = rng.below(3);
        let mut world = SimWorld::new(cfg);
        world.home(|s| {
            s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        });
        // content spanning several 64 KiB blocks, with a ragged tail
        let len = 3 * 64 * 1024 + rng.below((size as u64 + 1) * 4096).min(5 * 64 * 1024) + 17;
        let mut content = vec![0u8; len as usize];
        rng.fill_bytes(&mut content);
        world.home(|s| s.home_mut().write("/home/u/blob", &content, t(0.0)).unwrap());

        // paged client: random preads then a sequential scan
        let mut paged = world.mount("/home/u").map_err(|e| e.to_string())?;
        let fd = paged.open("/home/u/blob", OpenFlags::rdonly()).map_err(|e| e.to_string())?;
        for _ in 0..6 {
            let off = rng.below(len + 8192);
            let want = rng.range(1, 3 * 64 * 1024) as usize;
            let mut buf = vec![0u8; want];
            let n = paged.pread(fd, &mut buf, off).map_err(|e| e.to_string())?;
            let expect: &[u8] = if (off as usize) < content.len() {
                &content[off as usize..(off as usize + want).min(content.len())]
            } else {
                &[]
            };
            prop_assert_eq!(n, expect.len());
            prop_assert!(&buf[..n] == expect, "paged pread mismatch at {off}");
        }
        let mut scanned = Vec::new();
        let mut chunk = vec![0u8; 50_000];
        loop {
            let n = paged.read(fd, &mut chunk).map_err(|e| e.to_string())?;
            if n == 0 {
                break;
            }
            scanned.extend_from_slice(&chunk[..n]);
        }
        paged.close(fd).map_err(|e| e.to_string())?;
        prop_assert_eq!(scanned.len(), content.len());
        prop_assert!(scanned == content, "paged scan does not match home content");
        prop_assert!(
            paged.metrics().counter(names::RANGE_FETCHES) > 0,
            "paged client must have used range fetches"
        );

        // whole-file-mode client reads the identical bytes
        let mut whole = world.mount("/home/u").map_err(|e| e.to_string())?;
        whole.paging = false;
        let fd = whole.open("/home/u/blob", OpenFlags::rdonly()).map_err(|e| e.to_string())?;
        let mut scanned = Vec::new();
        loop {
            let n = whole.read(fd, &mut chunk).map_err(|e| e.to_string())?;
            if n == 0 {
                break;
            }
            scanned.extend_from_slice(&chunk[..n]);
        }
        whole.close(fd).map_err(|e| e.to_string())?;
        prop_assert!(scanned == content, "whole-file scan does not match home content");
        Ok(())
    });
}

/// Pipelined readahead (DESIGN.md §2.12) must be invisible to the data
/// plane: a client with speculative pipelining enabled — whatever
/// block/readahead geometry and whatever hint hit/eviction/dead-hint
/// pattern the run produces — returns byte-identical content for random
/// positional reads and a full sequential scan.
#[test]
fn prop_pipelined_readahead_is_byte_identical() {
    prop::check(15, |rng, size| {
        let mut cfg = XufsConfig::default();
        cfg.cache.readahead_blocks = rng.below(3);
        cfg.transfer.pipeline = true;
        cfg.transfer.pipeline_window = (rng.below(3) + 1) as usize;
        let mut world = SimWorld::new(cfg);
        world.home(|s| {
            s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        });
        let len = 3 * 64 * 1024 + rng.below((size as u64 + 1) * 4096).min(5 * 64 * 1024) + 17;
        let mut content = vec![0u8; len as usize];
        rng.fill_bytes(&mut content);
        world.home(|s| s.home_mut().write("/home/u/blob", &content, t(0.0)).unwrap());

        let mut c = world.mount("/home/u").map_err(|e| e.to_string())?;
        let fd = c.open("/home/u/blob", OpenFlags::rdonly()).map_err(|e| e.to_string())?;
        for _ in 0..6 {
            let off = rng.below(len + 8192);
            let want = rng.range(1, 3 * 64 * 1024) as usize;
            let mut buf = vec![0u8; want];
            let n = c.pread(fd, &mut buf, off).map_err(|e| e.to_string())?;
            let expect: &[u8] = if (off as usize) < content.len() {
                &content[off as usize..(off as usize + want).min(content.len())]
            } else {
                &[]
            };
            prop_assert_eq!(n, expect.len());
            prop_assert!(&buf[..n] == expect, "pipelined pread mismatch at {off}");
        }
        let mut scanned = Vec::new();
        let mut chunk = vec![0u8; 50_000];
        loop {
            let n = c.read(fd, &mut chunk).map_err(|e| e.to_string())?;
            if n == 0 {
                break;
            }
            scanned.extend_from_slice(&chunk[..n]);
        }
        c.close(fd).map_err(|e| e.to_string())?;
        prop_assert_eq!(scanned.len(), content.len());
        prop_assert!(scanned == content, "pipelined scan does not match home content");
        prop_assert!(
            c.metrics().counter(names::RANGE_FETCHES) > 0,
            "pipelined client must still use range fetches"
        );
        Ok(())
    });
}

#[test]
fn pread_leaves_cursor_for_sequential_read() {
    let mut l = local();
    l.write_file("/f", b"abcdef", 16).unwrap();
    let fd = l.open("/f", OpenFlags::rdonly()).unwrap();
    let mut b2 = [0u8; 2];
    assert_eq!(l.read(fd, &mut b2).unwrap(), 2);
    assert_eq!(&b2, b"ab");
    // positional read elsewhere...
    assert_eq!(l.pread(fd, &mut b2, 4).unwrap(), 2);
    assert_eq!(&b2, b"ef");
    // ...does not disturb the sequential cursor
    assert_eq!(l.tell(fd).unwrap(), 2);
    assert_eq!(l.read(fd, &mut b2).unwrap(), 2);
    assert_eq!(&b2, b"cd");
    l.close(fd).unwrap();
}

#[test]
fn append_flag_starts_cursor_at_eof() {
    let mut l = local();
    l.write_file("/log", b"one\n", 16).unwrap();
    let fd = l.open("/log", OpenFlags::append()).unwrap();
    assert_eq!(l.tell(fd).unwrap(), 4);
    l.write(fd, b"two\n").unwrap();
    l.close(fd).unwrap();
    assert_eq!(l.fs.read("/log").unwrap(), b"one\ntwo\n");
}

#[test]
fn invalid_flags_rejected_at_open_by_every_implementor() {
    let bad = [
        OpenFlags::empty(),
        OpenFlags::READ | OpenFlags::TRUNCATE,
        OpenFlags::READ | OpenFlags::CREATE,
        OpenFlags::WRITE | OpenFlags::TRUNCATE | OpenFlags::APPEND,
    ];
    // LocalFs
    let mut l = local();
    l.write_file("/f", b"x", 4).unwrap();
    for f in bad {
        assert!(matches!(l.open("/f", f), Err(FsError::Invalid(_))), "LocalFs {f:?}");
    }
    // XufsClient
    let mut world = world_with_home();
    world.home(|s| s.home_mut().write("/home/u/f", b"x", t(0.0)).unwrap());
    let mut c = world.mount("/home/u").unwrap();
    for f in bad {
        assert!(matches!(c.open("/home/u/f", f), Err(FsError::Invalid(_))), "Xufs {f:?}");
    }
    // GpfsWan
    let clock = Arc::new(SimClock::new());
    let mut fs = FileStore::default();
    fs.write("/f", b"x", t(0.0)).unwrap();
    let mut g = xufs::baselines::GpfsWan::new(fs.clone(), xufs::baselines::GpfsWanParams::default(), clock.clone());
    for f in bad {
        assert!(matches!(g.open("/f", f), Err(FsError::Invalid(_))), "Gpfs {f:?}");
    }
    // NfsClient
    let wan = Arc::new(xufs::simnet::Wan::new(xufs::config::WanConfig::default(), (*clock).clone()));
    let mut n = xufs::baselines::NfsClient::new(fs, clock, wan, DiskModel::new(400.0e6, 0.002), 1);
    for f in bad {
        assert!(matches!(n.open("/f", f), Err(FsError::Invalid(_))), "Nfs {f:?}");
    }
}

#[test]
fn compound_flush_is_single_round_trip() {
    let mut world = world_with_home();
    let mut c = world.mount("/home/u").unwrap();
    c.writeback = WritebackMode::Async;
    c.async_flush_threshold = usize::MAX;
    for i in 0..8 {
        c.write_file(&format!("/home/u/f{i}.dat"), b"compound payload", 4096).unwrap();
    }
    let k = c.queue_len();
    assert!(k >= 16, "each file queues a Create + a WriteFull (got {k})");
    let rpcs_before = world.wan.stats().rpcs;
    let frames_before = c.metrics().counter(names::COMPOUND_RPCS);
    let ops_before = c.metrics().counter(names::COMPOUND_OPS);
    c.fsync().unwrap();
    assert_eq!(c.queue_len(), 0);
    assert_eq!(
        c.metrics().counter(names::COMPOUND_RPCS),
        frames_before + 1,
        "K queued ops must flush as exactly one Request::Compound"
    );
    assert_eq!(c.metrics().counter(names::COMPOUND_OPS), ops_before + k as u64);
    assert_eq!(
        world.wan.stats().rpcs,
        rpcs_before + 1,
        "one WAN round trip for the whole queue"
    );
    for i in 0..8 {
        let home = world.home(|s| s.home().read(&format!("/home/u/f{i}.dat")).unwrap().to_vec());
        assert_eq!(home, b"compound payload");
    }
}

#[test]
fn compound_partial_failure_drops_only_failed_ops() {
    let mut world = world_with_home();
    let mut c = world.mount("/home/u").unwrap();
    c.writeback = WritebackMode::Async;
    c.async_flush_threshold = usize::MAX;
    c.write_file("/home/u/good1.dat", b"ok", 4096).unwrap();
    // /home/u/ghost does not exist at home and no Mkdir is queued for it:
    // the server rejects this file's Create + WriteFull semantically
    c.write_file("/home/u/ghost/bad.dat", b"nope", 4096).unwrap();
    c.write_file("/home/u/good2.dat", b"ok too", 4096).unwrap();
    // missing-target failures (code 2) are the replay-on-ghost class:
    // they are skipped (counted) rather than surfaced as apply errors
    let skipped_before = c.metrics().counter(names::METAQ_REPLAY_SKIPPED);
    c.fsync().unwrap();
    assert_eq!(c.queue_len(), 0, "failed ops are dropped, not wedged");
    assert_eq!(c.metrics().counter(names::METAQ_REPLAY_SKIPPED), skipped_before + 2);
    world.home(|s| {
        assert_eq!(s.home().read("/home/u/good1.dat").unwrap(), b"ok");
        assert_eq!(s.home().read("/home/u/good2.dat").unwrap(), b"ok too");
        assert!(!s.home().exists("/home/u/ghost/bad.dat"));
    });
    // the local cache keeps serving the local truth for the failed file
    assert_eq!(c.scan_file("/home/u/ghost/bad.dat", 4096).unwrap(), 4);
}

#[test]
fn compound_flush_survives_disconnection_and_replays() {
    let mut world = world_with_home();
    let mut c = world.mount("/home/u").unwrap();
    c.writeback = WritebackMode::Async;
    c.async_flush_threshold = usize::MAX;
    for i in 0..4 {
        c.write_file(&format!("/home/u/off{i}.txt"), b"queued", 4096).unwrap();
    }
    let k = c.queue_len();
    c.link_mut().set_network(false);
    // flush during the outage: nothing acknowledged, nothing lost
    c.fsync().unwrap();
    assert_eq!(c.queue_len(), k);
    c.link_mut().set_network(true);
    c.link_mut().reconnect().unwrap();
    c.fsync().unwrap();
    assert_eq!(c.queue_len(), 0);
    for i in 0..4 {
        assert!(world.home(|s| s.home().exists(&format!("/home/u/off{i}.txt"))));
    }
}

#[test]
fn batch_resolves_stats_in_one_compound_and_reports_per_op() {
    let mut world = world_with_home();
    world.home(|s| {
        s.home_mut().write("/home/u/a.txt", b"alpha", t(0.0)).unwrap();
        s.home_mut().write("/home/u/b.txt", b"beta!!", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    let rpcs_before = world.wan.stats().rpcs;
    let results = c
        .batch(&[
            MetaBatchOp::Mkdir { path: "/home/u/newdir".into() },
            MetaBatchOp::Stat { path: "/home/u/a.txt".into() },
            MetaBatchOp::Stat { path: "/home/u/b.txt".into() },
            MetaBatchOp::Stat { path: "/home/u/missing.txt".into() },
        ])
        .unwrap();
    assert_eq!(results.len(), 4);
    assert_eq!(results[0], MetaResult::Done);
    assert_eq!(results[1].attr().map(|a| a.size), Some(5));
    assert_eq!(results[2].attr().map(|a| a.size), Some(6));
    assert!(matches!(results[3], MetaResult::Err(FsError::NotFound(_))));
    // one compound for the three stats + one compound flushing the mkdir:
    // 4 meta-ops, 2 WAN round trips (v1: 4+)
    assert_eq!(world.wan.stats().rpcs, rpcs_before + 2);
    assert!(world.home(|s| s.home().exists("/home/u/newdir")));
    assert_eq!(c.queue_len(), 0);
}

#[test]
fn batch_stat_observes_earlier_mutation_in_same_batch() {
    // sync-on-close equivalence: the batch's mutations flush before its
    // server-side stats resolve, so "unlink then stat" inside one batch
    // reports NotFound — same as the sequential lowering would
    let mut world = world_with_home();
    world.home(|s| {
        s.home_mut().write("/home/u/doomed.txt", b"bye", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    let results = c
        .batch(&[
            MetaBatchOp::Unlink { path: "/home/u/doomed.txt".into() },
            MetaBatchOp::Stat { path: "/home/u/doomed.txt".into() },
        ])
        .unwrap();
    assert_eq!(results[0], MetaResult::Done);
    assert!(
        matches!(results[1], MetaResult::Err(FsError::NotFound(_))),
        "stat in the same batch must see the unlink: {:?}",
        results[1]
    );
    assert!(!world.home(|s| s.home().exists("/home/u/doomed.txt")));
}

#[test]
fn batch_stat_before_mutation_sees_premutation_state() {
    // the other direction of sequential equivalence: a stat BEFORE a
    // mutation of the same path in the same batch must report the
    // pre-mutation state, even though both ride compound round trips
    let mut world = world_with_home();
    world.home(|s| {
        s.home_mut().write("/home/u/shrink.txt", b"original content", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    let results = c
        .batch(&[
            MetaBatchOp::Stat { path: "/home/u/shrink.txt".into() },
            MetaBatchOp::Truncate { path: "/home/u/shrink.txt".into(), size: 0 },
            MetaBatchOp::Stat { path: "/home/u/shrink.txt".into() },
        ])
        .unwrap();
    assert_eq!(
        results[0].attr().map(|a| a.size),
        Some(16),
        "stat before the truncate must see the original size: {:?}",
        results[0]
    );
    assert_eq!(results[1], MetaResult::Done);
    assert_eq!(
        results[2].attr().map(|a| a.size),
        Some(0),
        "stat after the truncate must see the new size: {:?}",
        results[2]
    );
    assert_eq!(world.home(|s| s.home().stat("/home/u/shrink.txt").unwrap().size), 0);
}

#[test]
fn batch_default_impl_reports_per_op_results() {
    let mut l = local();
    l.write_file("/d/f.txt", b"seven!!", 16).unwrap();
    let results = l
        .batch(&[
            MetaBatchOp::Mkdir { path: "/d/sub".into() },
            MetaBatchOp::Stat { path: "/d/f.txt".into() },
            MetaBatchOp::Unlink { path: "/d/nothere".into() },
            MetaBatchOp::Rename { from: "/d/f.txt".into(), to: "/d/g.txt".into() },
            MetaBatchOp::Truncate { path: "/d/g.txt".into(), size: 3 },
        ])
        .unwrap();
    assert_eq!(results[0], MetaResult::Done);
    assert_eq!(results[1].attr().map(|a| a.size), Some(7));
    assert!(results[2].is_err(), "unlink of a missing file fails per-op");
    assert_eq!(results[3], MetaResult::Done);
    assert_eq!(results[4], MetaResult::Done);
    assert_eq!(l.fs.read("/d/g.txt").unwrap(), b"sev");
}

// ---------------------------------------------------------------------
// convenience defaults must close the fd on EVERY path
// ---------------------------------------------------------------------

/// Minimal failure-injecting Vfs for exercising the default methods.
struct FailingFs {
    next_fd: u64,
    open_fds: Vec<u64>,
    closed: Vec<u64>,
    fail_read: bool,
    fail_write: bool,
}

impl FailingFs {
    fn new(fail_read: bool, fail_write: bool) -> Self {
        FailingFs { next_fd: 3, open_fds: Vec::new(), closed: Vec::new(), fail_read, fail_write }
    }

    fn leaked(&self) -> usize {
        self.open_fds.iter().filter(|fd| !self.closed.contains(fd)).count()
    }
}

impl Vfs for FailingFs {
    fn open(&mut self, _path: &str, flags: OpenFlags) -> Result<Fd, FsError> {
        flags.validate()?;
        let fd = self.next_fd;
        self.next_fd += 1;
        self.open_fds.push(fd);
        Ok(Fd(fd))
    }
    fn pread(&mut self, _fd: Fd, buf: &mut [u8], _off: u64) -> Result<usize, FsError> {
        if self.fail_read {
            Err(FsError::Protocol("injected read failure".into()))
        } else {
            buf.fill(0);
            Ok(0)
        }
    }
    fn pwrite(&mut self, _fd: Fd, buf: &[u8], _off: u64) -> Result<usize, FsError> {
        if self.fail_write {
            Err(FsError::NoSpace)
        } else {
            Ok(buf.len())
        }
    }
    fn seek(&mut self, _fd: Fd, _pos: u64) -> Result<(), FsError> {
        Ok(())
    }
    fn tell(&self, _fd: Fd) -> Result<u64, FsError> {
        Ok(0)
    }
    fn close(&mut self, fd: Fd) -> Result<(), FsError> {
        self.closed.push(fd.0);
        Ok(())
    }
    fn stat(&mut self, path: &str) -> Result<WireAttr, FsError> {
        Err(FsError::NotFound(path.into()))
    }
    fn readdir(&mut self, path: &str) -> Result<Vec<(String, WireAttr)>, FsError> {
        Err(FsError::NotFound(path.into()))
    }
    fn chdir(&mut self, _path: &str) -> Result<(), FsError> {
        Ok(())
    }
    fn mkdir(&mut self, _path: &str) -> Result<(), FsError> {
        Ok(())
    }
    fn unlink(&mut self, _path: &str) -> Result<(), FsError> {
        Ok(())
    }
    fn rename(&mut self, _from: &str, _to: &str) -> Result<(), FsError> {
        Ok(())
    }
    fn truncate(&mut self, _path: &str, _size: u64) -> Result<(), FsError> {
        Ok(())
    }
    fn lock(&mut self, _fd: Fd, _kind: LockKind) -> Result<(), FsError> {
        Ok(())
    }
    fn unlock(&mut self, _fd: Fd) -> Result<(), FsError> {
        Ok(())
    }
    fn fsync(&mut self) -> Result<(), FsError> {
        Ok(())
    }
    fn now(&self) -> VirtualTime {
        VirtualTime::ZERO
    }
}

#[test]
fn scan_file_closes_fd_on_read_error() {
    let mut f = FailingFs::new(true, false);
    assert!(f.scan_file("/x", 64).is_err());
    assert_eq!(f.leaked(), 0, "the fd must be closed on the error path");
}

#[test]
fn write_file_closes_fd_on_write_error() {
    let mut f = FailingFs::new(false, true);
    assert!(f.write_file("/x", b"data", 2).is_err());
    assert_eq!(f.leaked(), 0, "the fd must be closed on the error path");
}

#[test]
fn defaults_close_fd_on_success_too() {
    let mut f = FailingFs::new(false, false);
    f.write_file("/x", b"data", 2).unwrap();
    f.scan_file("/x", 64).unwrap();
    assert_eq!(f.leaked(), 0);
}
