//! Property tests on the coordinator invariants (DESIGN.md §6), using the
//! in-crate prop harness (`xufs::util::prop` — the offline stand-in for
//! proptest). Each property runs hundreds of seeded random cases; failures
//! report the seed.

use std::sync::Arc;

use xufs::client::{OpenFlags, ServerLink, Vfs, WritebackMode};
use xufs::config::XufsConfig;
use xufs::coordinator::SimWorld;
use xufs::homefs::FileStore;
use xufs::lease::{Acquire, LockTable};
use xufs::metaq::MetaQueue;
use xufs::metrics::Metrics;
use xufs::proto::{LockKind, MetaOp, Request, Response};
use xufs::runtime::{block_byte_sizes, DigestEngine};
use xufs::simnet::VirtualTime;
use xufs::util::{prop, Rng};
use xufs::{prop_assert, prop_assert_eq};

fn t(s: f64) -> VirtualTime {
    VirtualTime::from_secs(s)
}

/// Random mutating op over a small path universe.
fn random_op(rng: &mut Rng) -> MetaOp {
    let path = format!("/home/u/f{}", rng.below(6));
    match rng.below(6) {
        0 => MetaOp::Mkdir { path: format!("/home/u/d{}", rng.below(3)) },
        1 => MetaOp::Create { path },
        2 => {
            let mut data = vec![0u8; rng.range(1, 4096) as usize];
            rng.fill_bytes(&mut data);
            MetaOp::WriteFull { path, data, digests: vec![], base_version: 0 }
        }
        3 => MetaOp::Truncate { path, size: rng.below(2048) },
        4 => MetaOp::SetMode { path, mode: 0o600 | (rng.below(0o77) as u32) },
        _ => MetaOp::Unlink { path },
    }
}

/// Apply an op directly to a reference store, mirroring server semantics
/// (errors ignored — the server drops semantically failing replays too).
fn apply_ref(fs: &mut FileStore, op: &MetaOp, now: VirtualTime) {
    let _ = match op {
        MetaOp::Mkdir { path } => fs.mkdir_p(path, now).map(|_| ()),
        MetaOp::Create { path } => match fs.create(path, now) {
            Ok(_) => Ok(()),
            Err(_) => Ok(()),
        },
        MetaOp::WriteFull { path, data, .. } => fs.write(path, data, now),
        MetaOp::Truncate { path, size } => fs.truncate(path, *size, now),
        MetaOp::SetMode { path, mode } => fs.set_mode(path, *mode, now),
        MetaOp::Unlink { path } => fs.unlink(path, now),
        _ => Ok(()),
    };
}

#[test]
fn prop_queue_replay_is_idempotent_and_ordered() {
    // A crashed client's persisted queue, replayed (possibly with
    // duplicate deliveries), must leave the home space exactly as an
    // uncrashed client would have.
    prop::check(60, |rng, size| {
        let mut cfg = XufsConfig::default();
        cfg.seed = rng.next_u64();
        let mut world = SimWorld::new(cfg);
        world.home(|s| {
            s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        });
        let mut reference = world.home(|s| s.home().clone());

        let n_ops = 1 + rng.below(size as u64 * 2) as usize;
        let ops: Vec<MetaOp> = (0..n_ops).map(|_| random_op(rng)).collect();

        // reference: ops applied in order, once
        for op in &ops {
            apply_ref(&mut reference, op, t(1.0));
        }

        // system under test: queue everything, then replay with random
        // duplicate deliveries (ship is idempotent per seq)
        let mut client = world.mount("/home/u").map_err(|e| e.to_string())?;
        client.writeback = WritebackMode::Async;
        client.async_flush_threshold = usize::MAX;
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        for op in &ops {
            q.append(&mut store, op.clone(), t(1.0)).map_err(|e| e.to_string())?;
        }
        for (seq, op) in q.pending().to_vec() {
            let deliveries = 1 + rng.below(2);
            for _ in 0..deliveries {
                let resp = client.link_mut().ship(seq, &op).map_err(|e| e.to_string())?;
                prop_assert!(matches!(resp, Response::Applied { .. } | Response::Err { .. }),
                    "unexpected response {resp:?}");
            }
        }

        // compare home spaces: same paths, same contents
        let got = world.home(|s| s.home().clone());
        let want_walk = reference.walk("/home/u").map_err(|e| e.to_string())?;
        let got_walk = got.walk("/home/u").map_err(|e| e.to_string())?;
        let wp: Vec<&String> = want_walk.iter().map(|(p, _)| p).collect();
        let gp: Vec<&String> = got_walk.iter().map(|(p, _)| p).collect();
        prop_assert_eq!(wp, gp);
        for (p, a) in &want_walk {
            if a.kind == xufs::homefs::NodeKind::File {
                prop_assert_eq!(reference.read(p).unwrap(), got.read(p).unwrap());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_last_close_wins() {
    // Two clients overwrite the same file; whoever closes last defines
    // the home-space content, regardless of open/write interleaving.
    prop::check(40, |rng, _size| {
        let mut world = SimWorld::new(XufsConfig::default());
        world.home(|s| {
            s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
            s.home_mut().write("/home/u/shared", b"orig", t(0.0)).unwrap();
        });
        let mut a = world.mount("/home/u").map_err(|e| e.to_string())?;
        let mut b = world.mount("/home/u").map_err(|e| e.to_string())?;

        let fa = a.open("/home/u/shared", OpenFlags::wronly_create()).map_err(|e| e.to_string())?;
        let fb = b.open("/home/u/shared", OpenFlags::wronly_create()).map_err(|e| e.to_string())?;
        // interleave writes randomly
        for _ in 0..rng.range(1, 6) {
            if rng.chance(0.5) {
                a.write(fa, b"AAAA").map_err(|e| e.to_string())?;
            } else {
                b.write(fb, b"BBBB").map_err(|e| e.to_string())?;
            }
        }
        a.write(fa, b"-from-a").map_err(|e| e.to_string())?;
        b.write(fb, b"-from-b").map_err(|e| e.to_string())?;
        // random close order — last close wins
        let a_last = rng.chance(0.5);
        if a_last {
            b.close(fb).map_err(|e| e.to_string())?;
            a.close(fa).map_err(|e| e.to_string())?;
        } else {
            a.close(fa).map_err(|e| e.to_string())?;
            b.close(fb).map_err(|e| e.to_string())?;
        }
        let home = world.home(|s| s.home().read("/home/u/shared").unwrap().to_vec());
        let suffix: &[u8] = if a_last { b"-from-a" } else { b"-from-b" };
        prop_assert!(home.ends_with(suffix), "home={:?} a_last={a_last}", String::from_utf8_lossy(&home));
        Ok(())
    });
}

#[test]
fn prop_disconnected_ops_never_block_on_network() {
    // once content is cached, reads/writes/closes during an outage
    // succeed locally and queue their effects
    prop::check(40, |rng, size| {
        let mut cfg = XufsConfig::default();
        cfg.seed = rng.next_u64();
        let mut world = SimWorld::new(cfg);
        let n_files = 1 + rng.below(size as u64).min(8);
        world.home(|s| {
            s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
            for i in 0..n_files {
                let mut data = vec![0u8; rng.range(16, 100_000) as usize];
                rng.fill_bytes(&mut data);
                s.home_mut().write(&format!("/home/u/f{i}"), &data, t(0.0)).unwrap();
            }
        });
        let mut c = world.mount("/home/u").map_err(|e| e.to_string())?;
        // cache everything while online
        for i in 0..n_files {
            c.scan_file(&format!("/home/u/f{i}"), 65536).map_err(|e| e.to_string())?;
        }
        c.link_mut().set_network(false);
        let wan_rpcs_before = world.wan.stats().rpcs;
        // random offline ops must all succeed
        for _ in 0..rng.range(2, 12) {
            let i = rng.below(n_files);
            match rng.below(3) {
                0 => {
                    c.scan_file(&format!("/home/u/f{i}"), 65536).map_err(|e| e.to_string())?;
                }
                1 => {
                    c.write_file(&format!("/home/u/f{i}"), b"offline edit", 4096)
                        .map_err(|e| e.to_string())?;
                }
                _ => {
                    c.stat(&format!("/home/u/f{i}")).map_err(|e| e.to_string())?;
                }
            }
        }
        prop_assert_eq!(world.wan.stats().rpcs, wan_rpcs_before);
        // reconnect drains the queue
        c.link_mut().set_network(true);
        c.link_mut().reconnect().map_err(|e| e.to_string())?;
        c.fsync().map_err(|e| e.to_string())?;
        prop_assert_eq!(c.queue_len(), 0);
        Ok(())
    });
}

#[test]
fn prop_lock_table_safety() {
    // never two concurrent exclusive holders on one path; shared locks
    // never coexist with an exclusive one
    prop::check(120, |rng, size| {
        let mut lt = LockTable::new(5.0);
        let mut now = 0.0f64;
        let mut held: Vec<(u64, String, LockKind, u64, f64)> = Vec::new(); // token,path,kind,owner,expiry
        for _ in 0..(size * 4).max(8) {
            now += rng.f64() * 2.0;
            held.retain(|h| h.4 > now);
            let path = format!("/f{}", rng.below(3));
            let owner = 1 + rng.below(4);
            let kind = if rng.chance(0.5) { LockKind::Exclusive } else { LockKind::Shared };
            match rng.below(3) {
                0 => match lt.acquire(&path, kind, owner, t(now)) {
                    Acquire::Granted { token, lease } => {
                        held.push((token, path.clone(), kind, owner, lease.as_secs()));
                    }
                    Acquire::Denied { .. } => {}
                },
                1 => {
                    if let Some(h) = held.last().cloned() {
                        if lt.renew(h.0, h.3, t(now)).is_some() {
                            held.last_mut().unwrap().4 = now + 5.0;
                        }
                    }
                }
                _ => {
                    if !held.is_empty() {
                        let i = rng.below(held.len() as u64) as usize;
                        let h = held.remove(i);
                        lt.release(h.0, h.3);
                    }
                }
            }
            // safety check over live (unexpired) locks per path
            for path in ["/f0", "/f1", "/f2"] {
                let live: Vec<_> = held
                    .iter()
                    .filter(|h| h.1 == path && h.4 > now)
                    .collect();
                let excl_owners: std::collections::BTreeSet<u64> = live
                    .iter()
                    .filter(|h| h.2 == LockKind::Exclusive)
                    .map(|h| h.3)
                    .collect();
                prop_assert!(excl_owners.len() <= 1, "two exclusive owners on {path}: {excl_owners:?}");
                if !excl_owners.is_empty() {
                    let others = live
                        .iter()
                        .filter(|h| h.2 == LockKind::Shared && !excl_owners.contains(&h.3))
                        .count();
                    prop_assert!(others == 0, "shared+exclusive mix on {path}");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stripe_plan_invariants() {
    let engine = DigestEngine::native(Metrics::new());
    prop::check(150, |rng, size| {
        let block = 4096usize;
        let n_blocks = 1 + rng.below(size as u64 * 2) as usize;
        let data_len = (n_blocks - 1) * block + 1 + rng.below(block as u64 - 1) as usize;
        let mut data = vec![0u8; data_len];
        rng.fill_bytes(&mut data);
        let mut old = engine.digests(&data, block);
        // flip a random subset to dirty
        let mut expect_dirty = vec![false; old.len()];
        for (i, d) in expect_dirty.iter_mut().enumerate() {
            if rng.chance(0.3) {
                old[i] ^= 1;
                *d = true;
            }
        }
        let stripes = 1 + rng.below(12) as usize;
        let plan = engine.plan(&data, &old, block, stripes);
        prop_assert_eq!(plan.dirty, expect_dirty);
        // clean blocks unassigned; dirty in [0, stripes); ids non-decreasing
        let mut last = -1i32;
        for (i, &s) in plan.stripe.iter().enumerate() {
            if plan.dirty[i] {
                prop_assert!(s >= 0 && (s as usize) < stripes, "block {i} stripe {s}");
                prop_assert!(s >= last, "stripe ids must be non-decreasing");
                last = s;
            } else {
                prop_assert_eq!(s, -1);
            }
        }
        // stripe payloads balanced within one block size
        if stripes > 1 && plan.dirty_blocks() > 0 {
            let sizes = block_byte_sizes(data_len, block, plan.digests.len());
            let mut loads = vec![0u64; stripes];
            for (i, &s) in plan.stripe.iter().enumerate() {
                if s >= 0 {
                    loads[s as usize] += sizes[i] as u64;
                }
            }
            let used: Vec<u64> = loads.iter().copied().filter(|&l| l > 0).collect();
            if used.len() > 1 {
                let max = *used.iter().max().unwrap();
                let min = *used.iter().min().unwrap();
                prop_assert!(max - min <= 2 * block as u64, "unbalanced: {loads:?}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_codec_fuzz_never_panics_and_roundtrips() {
    prop::check(300, |rng, size| {
        // random garbage must decode to Err, never panic
        let mut junk = vec![0u8; rng.below(size as u64 * 8 + 2) as usize];
        rng.fill_bytes(&mut junk);
        let _ = Request::decode(&junk);
        let _ = Response::decode(&junk);
        let _ = MetaOp::decode(&junk);
        // random valid messages roundtrip
        let op = random_op(rng);
        prop_assert_eq!(MetaOp::decode(&op.encode()).unwrap(), op);
        let req = Request::Apply { seq: rng.next_u64(), op: random_op(rng) };
        prop_assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        Ok(())
    });
}

#[test]
fn prop_cache_recovery_preserves_index() {
    // CacheSpace::recover over a random populated cache reproduces the
    // index (state machine of install/dirty/invalidate)
    prop::check(60, |rng, size| {
        use xufs::cache::{CacheSpace, EntryState};
        use xufs::homefs::NodeKind;
        use xufs::proto::WireAttr;
        let mut c = CacheSpace::new(u64::MAX, vec![]);
        let n = 1 + rng.below(size as u64).min(12);
        let mut expected: Vec<(String, EntryState, u64)> = Vec::new();
        for i in 0..n {
            let p = format!("/home/u/f{i}");
            let mut data = vec![0u8; rng.range(1, 5000) as usize];
            rng.fill_bytes(&mut data);
            let version = rng.range(1, 50);
            let attr = WireAttr {
                kind: NodeKind::File,
                size: data.len() as u64,
                mtime_ns: 0,
                mode: 0o600,
                version,
            };
            c.install(&p, &data, version, vec![i as i32], attr, t(1.0)).map_err(|e| e.to_string())?;
            let state = match rng.below(3) {
                0 => {
                    c.store_mut().write(&p, b"dirty", t(2.0)).map_err(|e| e.to_string())?;
                    c.mark_dirty(&p, vec![-1], t(2.0)).map_err(|e| e.to_string())?;
                    EntryState::Dirty
                }
                1 => {
                    c.invalidate(&p, t(2.0));
                    EntryState::Invalid
                }
                _ => EntryState::Clean,
            };
            expected.push((p, state, version));
        }
        let recovered = CacheSpace::recover(
            c.store().clone(),
            u64::MAX,
            vec![],
            t(9.0),
            &xufs::metrics::Metrics::new(),
        );
        for (p, state, version) in expected {
            let e = recovered.entry(&p).ok_or(format!("lost {p}"))?;
            prop_assert_eq!(e.state, state);
            if state == EntryState::Clean {
                prop_assert_eq!(e.version, version);
            }
        }
        Ok(())
    });
}
