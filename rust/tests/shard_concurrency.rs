//! Real-thread tests of the sharded concurrent server core
//! (DESIGN.md §2.6): multiple OS threads dispatch [`FileServer::handle`]
//! on one shared `Arc<FileServer>` with NO global lock, and the AFS-2
//! guarantees must hold exactly as they did under the old single mutex —
//! callback fanout crosses shard boundaries, per-client replay stays
//! idempotent, and concurrent writers to disjoint subtrees converge.

use std::sync::Arc;

use xufs::callback::NotifyChannel;
use xufs::config::ChunkstoreConfig;
use xufs::homefs::FileStore;
use xufs::metrics::{names, Metrics};
use xufs::proto::{MetaOp, NotifyEvent, Request, Response};
use xufs::runtime::DigestEngine;
use xufs::server::FileServer;
use xufs::simnet::VirtualTime;
use xufs::vdisk::DiskModel;

fn t(s: f64) -> VirtualTime {
    VirtualTime::from_secs(s)
}

fn server(shards: usize) -> (Arc<FileServer>, Metrics) {
    let mut fs = FileStore::default();
    fs.mkdir_p("/home/u", t(0.0)).unwrap();
    let metrics = Metrics::new();
    let s = FileServer::new(
        fs,
        DiskModel::new(200.0e6, 0.0005),
        Arc::new(DigestEngine::native(metrics.clone())),
        65536,
        30.0,
        shards,
        metrics.clone(),
        ChunkstoreConfig::default(),
    );
    (Arc::new(s), metrics)
}

/// Two paths under `dir` that provably route to DIFFERENT shards.
fn cross_shard_pair(s: &FileServer, dir: &str) -> (String, String) {
    let first = format!("{dir}/shardprobe0");
    let base = s.shard_of(&first);
    for i in 1..512 {
        let cand = format!("{dir}/shardprobe{i}");
        if s.shard_of(&cand) != base {
            return (first, cand);
        }
    }
    panic!("no cross-shard pair found in 512 candidates");
}

/// Satellite acceptance: two clients mutating DISJOINT shards from two
/// real threads both receive each other's invalidations — the replicated
/// callback registry makes fanout work without any cross-shard locking
/// on the hot path.
#[test]
fn concurrent_callback_fanout_across_disjoint_shards() {
    let (s, _m) = server(8);
    let ch1 = NotifyChannel::new();
    let ch2 = NotifyChannel::new();
    s.attach_channel(1, ch1.clone());
    s.attach_channel(2, ch2.clone());
    s.handle(1, Request::RegisterCallback { root: "/home/u".into(), client_id: 1 }, t(0.0));
    s.handle(2, Request::RegisterCallback { root: "/home/u".into(), client_id: 2 }, t(0.0));
    let (p1, p2) = cross_shard_pair(&s, "/home/u");
    assert_ne!(s.shard_of(&p1), s.shard_of(&p2), "the two writers hit disjoint shards");
    let mut handles = Vec::new();
    for (cid, path) in [(1u64, p1.clone()), (2u64, p2.clone())] {
        let s = s.clone();
        handles.push(std::thread::spawn(move || {
            for seq in 1..=50u64 {
                let r = s.handle(
                    cid,
                    Request::Apply {
                        seq,
                        op: MetaOp::WriteFull {
                            path: path.clone(),
                            data: vec![seq as u8; 512],
                            digests: vec![],
                            base_version: 0,
                        },
                    },
                    t(seq as f64),
                );
                assert!(matches!(r, Response::Applied { .. }), "{r:?}");
            }
        }));
    }
    for h in handles {
        h.join().expect("writer thread panicked");
    }
    // each client saw exactly the OTHER writer's 50 invalidations
    let evs1 = ch1.drain();
    let evs2 = ch2.drain();
    assert_eq!(evs1.len(), 50, "client 1 gets client 2's invalidations");
    assert!(evs1
        .iter()
        .all(|e| matches!(e, NotifyEvent::Invalidate { path, .. } if *path == p2)));
    assert_eq!(evs2.len(), 50, "client 2 gets client 1's invalidations");
    assert!(evs2
        .iter()
        .all(|e| matches!(e, NotifyEvent::Invalidate { path, .. } if *path == p1)));
}

/// Four threads of interleaved writes + neighbour reads converge to the
/// per-thread last-write truth, and replaying any already-applied
/// `(client, seq)` afterwards answers as a duplicate without a version
/// bump — the per-shard watermark is semantically the global one.
#[test]
fn concurrent_mixed_ops_converge_and_replay_stays_idempotent() {
    const THREADS: u64 = 4;
    const OPS: u64 = 40;
    let (s, _m) = server(8);
    let mut handles = Vec::new();
    for c in 0..THREADS {
        let s = s.clone();
        handles.push(std::thread::spawn(move || {
            let client = c + 1;
            let dir = format!("/home/u/t{client}");
            let r = s.handle(
                client,
                Request::Apply { seq: 1, op: MetaOp::Mkdir { path: dir.clone() } },
                t(0.0),
            );
            assert!(matches!(r, Response::Applied { .. }), "{r:?}");
            for k in 0..OPS {
                let seq = k + 2;
                let r = s.handle(
                    client,
                    Request::Apply {
                        seq,
                        op: MetaOp::WriteFull {
                            path: format!("{dir}/f{}", k % 8),
                            data: vec![(k % 251) as u8; 1024],
                            digests: vec![],
                            base_version: 0,
                        },
                    },
                    t(1.0),
                );
                assert!(matches!(r, Response::Applied { .. }), "{r:?}");
                // reads of a neighbour's subtree interleave freely (the
                // neighbour may not have created it yet — both answers
                // are legal, neither may wedge)
                let neighbour = (c + 1) % THREADS + 1;
                let _ = s.handle(
                    client,
                    Request::Stat { path: format!("/home/u/t{neighbour}/f0") },
                    t(1.0),
                );
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked");
    }
    // last write wins per file: for f{j} the last k with k % 8 == j is 32+j
    for c in 1..=THREADS {
        for j in 0..8u64 {
            let path = format!("/home/u/t{c}/f{j}");
            let data = s.home().read(&path).map(|d| d.to_vec()).expect(&path);
            assert_eq!(data, vec![((32 + j) % 251) as u8; 1024], "{path}");
        }
    }
    // replay an applied seq: duplicate answer, no re-apply
    let v = s.home().stat("/home/u/t1/f0").unwrap().version;
    let r = s.handle(
        1,
        Request::Apply {
            seq: 34,
            op: MetaOp::WriteFull {
                path: "/home/u/t1/f0".into(),
                data: vec![9u8; 16],
                digests: vec![],
                base_version: 0,
            },
        },
        t(9.0),
    );
    assert!(matches!(r, Response::Applied { seq: 34, .. }), "{r:?}");
    assert_eq!(s.home().stat("/home/u/t1/f0").unwrap().version, v, "no double apply");
}

/// The `shards = 1` ablation really is the single-lock server: with
/// modeled disk waits on, concurrent threads pile up on the one shard
/// and `server.shard_contention` shows it.
#[test]
fn single_shard_ablation_serializes_and_counts_contention() {
    let (s, m) = server(1);
    s.set_modeled_disk_waits(true);
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let s = s.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..20u64 {
                let r =
                    s.handle(c + 1, Request::Stat { path: format!("/home/u/p{c}_{i}") }, t(1.0));
                // the files don't exist — NotFound is the expected
                // answer; the point is the lock traffic
                assert!(matches!(r, Response::Err { code: 2, .. }), "{r:?}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(
        m.counter(names::SHARD_CONTENTION) > 0,
        "4 threads on 1 shard with real service waits must contend"
    );
}

/// Cross-shard renames from concurrent clients keep both namespaces
/// consistent (ordered two-shard locking, no deadlock) and count in
/// `server.cross_shard_ops`.
#[test]
fn concurrent_cross_shard_renames_are_deadlock_free() {
    let (s, m) = server(8);
    // each client gets its own provably-cross-shard (from, to) pair
    let mut pairs = Vec::new();
    for c in 0..4 {
        let (from, to) = cross_shard_pair(&s, &format!("/home/u/r{c}"));
        s.home_mut().mkdir_p(&format!("/home/u/r{c}"), t(0.0)).unwrap();
        s.home_mut().write(&from, format!("payload {c}").as_bytes(), t(0.0)).unwrap();
        pairs.push((from, to));
    }
    let mut handles = Vec::new();
    for (c, (from, to)) in pairs.iter().enumerate() {
        let s = s.clone();
        let (from, to) = (from.clone(), to.clone());
        handles.push(std::thread::spawn(move || {
            let r = s.handle(
                c as u64 + 1,
                Request::Apply { seq: 1, op: MetaOp::Rename { from, to } },
                t(1.0),
            );
            assert!(matches!(r, Response::Applied { .. }), "{r:?}");
        }));
    }
    for h in handles {
        h.join().expect("rename thread panicked");
    }
    for (c, (from, to)) in pairs.iter().enumerate() {
        assert!(!s.home().exists(from), "{from} moved");
        assert_eq!(
            s.home().read(to).map(|d| d.to_vec()),
            Ok(format!("payload {c}").into_bytes()),
            "{to}"
        );
    }
    assert!(
        m.counter(names::CROSS_SHARD_OPS) >= 4,
        "each rename took the ordered two-shard path"
    );
}
