//! Integration tests over the simulated deployment: the paper's §2.1
//! workflow end to end, multi-client consistency, cache pressure, and the
//! recovery tooling — the scenarios the unit tests cover only piecewise.

use xufs::client::{OpenFlags, ServerLink, Vfs, WritebackMode};
use xufs::config::XufsConfig;
use xufs::coordinator::SimWorld;
use xufs::metrics::names;
use xufs::simnet::VirtualTime;
use xufs::util::Rng;
use xufs::workload::{buildtree, largefile};

fn t(s: f64) -> VirtualTime {
    VirtualTime::from_secs(s)
}

#[test]
fn full_computational_science_workflow() {
    // develop -> mount -> build -> stage -> simulate -> analyze -> sync
    let mut cfg = XufsConfig::default();
    cfg.cache.localized_dirs = vec!["/home/sci/runs".into()];
    let mut world = SimWorld::new(cfg);
    let spec = buildtree::BuildSpec::default();
    world.home(|s| {
        buildtree::generate_tree(&mut s.home_mut(), "/home/sci/code", &spec, 3).unwrap();
        let input = largefile::text_content(8 << 20, 100, 5);
        s.home_mut().mkdir_p("/home/sci/data", t(0.0)).unwrap();
        s.home_mut().write("/home/sci/data/input.dat", &input, t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/sci").unwrap();

    let stats = buildtree::build(&mut c, "/home/sci/code", &spec).unwrap();
    assert_eq!(stats.sources_compiled, 24);
    // objects landed at home (the .o files are in the mounted tree)
    assert!(world.home(|s| s.home().exists("/home/sci/code/mod0/file00.o")));

    let n = c.scan_file("/home/sci/data/input.dat", 1 << 20).unwrap();
    assert_eq!(n, 8 << 20);

    // simulation writes raw output into the localized dir
    c.write_file("/home/sci/runs/raw.bin", &vec![9u8; 16 << 20], 1 << 20).unwrap();
    let (lines, _) = largefile::wc_l(&mut c, "/home/sci/runs/raw.bin", 1 << 20).unwrap();
    assert_eq!(lines, 0); // binary zeros... 9s actually: no newlines
    c.write_file("/home/sci/data/summary.txt", b"lines: 0\n", 4096).unwrap();

    assert!(world.home(|s| s.home().exists("/home/sci/data/summary.txt")));
    assert!(!world.home(|s| s.home().exists("/home/sci/runs/raw.bin")));
}

#[test]
fn three_clients_see_serialized_updates() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/counter", b"0", t(0.0)).unwrap();
    });
    let mut clients: Vec<_> = (0..3).map(|_| world.mount("/home/u").unwrap()).collect();
    for round in 1..=5u32 {
        let writer = (round as usize) % 3;
        let content = round.to_string();
        clients[writer].write_file("/home/u/counter", content.as_bytes(), 64).unwrap();
        // every other client observes the new value on next open
        for (i, c) in clients.iter_mut().enumerate() {
            if i == writer {
                continue;
            }
            let fd = c.open("/home/u/counter", OpenFlags::rdonly()).unwrap();
            let mut v = [0u8; 16];
            let n = c.read(fd, &mut v).unwrap();
            c.close(fd).unwrap();
            assert_eq!(&v[..n], content.as_bytes(), "round {round}, client {i}");
        }
    }
}

#[test]
fn cache_pressure_evicts_and_refetches() {
    let mut cfg = XufsConfig::default();
    cfg.cache.capacity = 6 << 20; // small cache
    let mut world = SimWorld::new(cfg);
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        for i in 0..4 {
            s.home_mut().write(&format!("/home/u/f{i}"), &vec![i as u8; 2 << 20], t(0.0)).unwrap();
        }
    });
    let mut c = world.mount("/home/u").unwrap();
    for i in 0..4 {
        c.scan_file(&format!("/home/u/f{i}"), 1 << 20).unwrap();
    }
    // the cache can't hold all four 2 MiB files + metadata
    assert!(c.cache().used_bytes() <= 6 << 20);
    // evicted file is refetched transparently (extra miss, correct bytes)
    let misses_before = c.metrics().counter(names::CACHE_MISSES);
    let n = c.scan_file("/home/u/f0", 1 << 20).unwrap();
    assert_eq!(n, 2 << 20);
    assert!(c.metrics().counter(names::CACHE_MISSES) >= misses_before);
}

#[test]
fn rename_and_unlink_propagate_both_ways() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/old.txt", b"content", t(0.0)).unwrap();
        s.home_mut().write("/home/u/gone.txt", b"bye", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.scan_file("/home/u/old.txt", 4096).unwrap();
    c.scan_file("/home/u/gone.txt", 4096).unwrap();

    // client-side rename + unlink reach the home space
    c.rename("/home/u/old.txt", "/home/u/new.txt").unwrap();
    c.unlink("/home/u/gone.txt").unwrap();
    world.home(|s| {
        assert!(s.home().exists("/home/u/new.txt"));
        assert!(!s.home().exists("/home/u/old.txt"));
        assert!(!s.home().exists("/home/u/gone.txt"));
    });

    // home-side removal invalidates the cached copy
    world.home(|s| s.local_unlink("/home/u/new.txt", t(50.0)).unwrap());
    assert!(c.open("/home/u/new.txt", OpenFlags::rdonly()).is_err());
}

#[test]
fn async_writeback_hides_wan_latency() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.writeback = WritebackMode::Async;
    c.async_flush_threshold = usize::MAX;
    let t0 = c.now();
    for i in 0..10 {
        c.write_file(&format!("/home/u/out{i}.dat"), &vec![1u8; 256 * 1024], 65536).unwrap();
    }
    let async_secs = c.now().saturating_sub(t0).as_secs();
    assert!(c.queue_len() >= 10);
    c.fsync().unwrap();
    assert_eq!(c.queue_len(), 0);

    // same workload, sync mode, fresh world
    let mut world2 = SimWorld::new(XufsConfig::default());
    world2.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
    });
    let mut c2 = world2.mount("/home/u").unwrap();
    let t1 = c2.now();
    for i in 0..10 {
        c2.write_file(&format!("/home/u/out{i}.dat"), &vec![1u8; 256 * 1024], 65536).unwrap();
    }
    let sync_secs = c2.now().saturating_sub(t1).as_secs();
    assert!(
        async_secs < sync_secs / 2.0,
        "async {async_secs} should hide most of sync {sync_secs}"
    );
}

#[test]
fn delta_writeback_ships_fraction_of_file() {
    let mut world = SimWorld::new(XufsConfig::default());
    let mut rng = Rng::new(8);
    let mut data = vec![0u8; 8 << 20];
    rng.fill_bytes(&mut data);
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/big.bin", &data, t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.scan_file("/home/u/big.bin", 1 << 20).unwrap();
    // in-place edit of one 64 KiB region
    let fd = c.open("/home/u/big.bin", OpenFlags::rdwr()).unwrap();
    c.seek(fd, 3 << 20).unwrap();
    c.write(fd, &vec![0xEEu8; 64 * 1024]).unwrap();
    c.close(fd).unwrap();
    // the delta plan shipped ~1 block, not ~8 MiB
    let shipped = c.metrics().counter(names::WRITEBACK_BYTES);
    assert!(shipped < 200 * 1024, "shipped {shipped}");
    // and the home copy is byte-correct
    let mut expect = data.clone();
    expect[3 << 20..(3 << 20) + 64 * 1024].copy_from_slice(&[0xEEu8; 64 * 1024]);
    let home = world.home(|s| s.home().read("/home/u/big.bin").unwrap().to_vec());
    assert!(home == expect, "delta-applied home copy must be bit-exact");
}

#[test]
fn corrupted_stale_delta_falls_back_to_full_write() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/doc.bin", &vec![1u8; 2 << 20], t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.writeback = WritebackMode::Async;
    c.async_flush_threshold = usize::MAX;
    c.scan_file("/home/u/doc.bin", 1 << 20).unwrap();
    // edit one block (delta candidate), but the home copy changes
    // concurrently so the delta base goes stale
    let fd = c.open("/home/u/doc.bin", OpenFlags::rdwr()).unwrap();
    c.write(fd, &vec![2u8; 64 * 1024]).unwrap();
    c.close(fd).unwrap();
    world.home(|s| s.local_write("/home/u/doc.bin", &vec![3u8; 2 << 20], t(60.0)).unwrap());
    // flush: server refuses the stale delta; client demotes to full write
    c.fsync().unwrap();
    assert_eq!(c.queue_len(), 0);
    let home = world.home(|s| s.home().read("/home/u/doc.bin").unwrap().to_vec());
    // last-close-wins: our aggregated content (edit over the v1 image)
    assert_eq!(&home[..64 * 1024], &vec![2u8; 64 * 1024][..]);
    assert_eq!(home.len(), 2 << 20);
}

#[test]
fn mount_auth_failure_is_clean() {
    // wrong phrase => mount-time auth failure surfaces as Perm, and the
    // server counts it
    let mut world = SimWorld::new(XufsConfig::default());
    // sabotage: replace the authenticator with one for a different pair
    {
        let mut rng = Rng::new(0xBAD);
        let other = xufs::auth::KeyPair::generate(&mut rng, t(0.0), 3600.0);
        *world.auth.lock().unwrap() = xufs::auth::Authenticator::new(other, 1);
    }
    let err = world.mount("/home/u").err().expect("mount must fail");
    assert!(matches!(err, xufs::homefs::FsError::Perm(_)), "{err:?}");
    assert_eq!(world.metrics.counter(names::AUTH_FAILURES), 1);
}

#[test]
fn reconnect_revalidates_suspect_entries() {
    let mut world = SimWorld::new(XufsConfig::default());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", t(0.0)).unwrap();
        s.home_mut().write("/home/u/a.txt", b"v1", t(0.0)).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    c.scan_file("/home/u/a.txt", 4096).unwrap();
    // outage; the home copy changes while the callback channel is down
    c.link_mut().set_network(false);
    world.home(|s| s.local_write("/home/u/a.txt", b"v2-while-away", t(100.0)).unwrap());
    c.link_mut().set_network(true);
    c.link_mut().reconnect().unwrap();
    // the lost invalidation cannot be trusted away: reopen re-fetches
    let fd = c.open("/home/u/a.txt", OpenFlags::rdonly()).unwrap();
    let mut v = [0u8; 64];
    let n = c.read(fd, &mut v).unwrap();
    c.close(fd).unwrap();
    assert_eq!(&v[..n], b"v2-while-away");
}
