//! PJRT runtime integration: the AOT HLO artifacts produced by
//! `python/compile/aot.py` must execute via the rust PJRT client and agree
//! **bit-for-bit** with the native digest engine (which is itself pinned
//! by golden vectors shared with the python tests).
//!
//! Needs the `pjrt` cargo feature (the `xla` bindings are not in the
//! offline crate set) and is skipped gracefully when `artifacts/` hasn't
//! been built yet (run `make artifacts` first).
#![cfg(feature = "pjrt")]

use xufs::metrics::Metrics;
use xufs::runtime::{block_byte_sizes, DigestEngine};
use xufs::util::Rng;

fn engines() -> Option<(DigestEngine, DigestEngine)> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts/ not built; skipping PJRT tests");
        return None;
    }
    let pjrt = DigestEngine::from_artifacts("artifacts", Metrics::new()).expect("load artifacts");
    assert!(pjrt.is_pjrt(), "manifest present but PJRT engine not constructed");
    Some((pjrt, DigestEngine::native(Metrics::new())))
}

#[test]
fn digests_match_native_exact_variant_shapes() {
    let Some((pjrt, native)) = engines() else { return };
    let mut rng = Rng::new(42);
    // exactly 64 blocks x 64 KiB: hits the big digest variant
    let mut data = vec![0u8; 64 * 65536];
    rng.fill_bytes(&mut data);
    assert_eq!(pjrt.digests_via_pjrt(&data, 65536).unwrap(), native.digests(&data, 65536));
    // exactly 16 blocks x 4 KiB: the small-block variant
    let mut small = vec![0u8; 16 * 4096];
    rng.fill_bytes(&mut small);
    assert_eq!(pjrt.digests_via_pjrt(&small, 4096).unwrap(), native.digests(&small, 4096));
}

#[test]
fn digests_match_native_ragged_sizes() {
    let Some((pjrt, native)) = engines() else { return };
    let mut rng = Rng::new(43);
    for size in [0usize, 1, 4095, 65536, 65537, 700_001, 5 << 20] {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        assert_eq!(
            pjrt.digests_via_pjrt(&data, 65536).unwrap(),
            native.digests(&data, 65536),
            "size {size}"
        );
    }
}

#[test]
fn fused_plan_variant_matches_native() {
    let Some((pjrt, native)) = engines() else { return };
    let mut rng = Rng::new(44);
    // exactly the plan_16x1024_s12 geometry: 16 blocks x 4 KiB, 12 stripes
    let mut data = vec![0u8; 16 * 4096];
    rng.fill_bytes(&mut data);
    let old = native.digests(&data, 4096);
    // dirty three blocks
    data[0] ^= 1;
    data[5 * 4096] ^= 1;
    data[15 * 4096] ^= 1;
    let p = pjrt.plan(&data, &old, 4096, 12);
    let n = native.plan(&data, &old, 4096, 12);
    assert_eq!(p.digests, n.digests);
    assert_eq!(p.dirty, n.dirty);
    assert_eq!(p.stripe, n.stripe);
    assert_eq!(p.dirty_blocks(), 3);
}

#[test]
fn plan_arbitrary_geometry_matches_native() {
    let Some((pjrt, native)) = engines() else { return };
    let mut rng = Rng::new(45);
    let mut data = vec![0u8; 3 * 65536 + 777];
    rng.fill_bytes(&mut data);
    let old = native.digests(&data, 65536);
    data[100_000] ^= 0xFF;
    let p = pjrt.plan(&data, &old, 65536, 12);
    let n = native.plan(&data, &old, 65536, 12);
    assert_eq!(p, n);
    assert_eq!(p.dirty, vec![false, true, false, false]);
}

#[test]
fn corruption_detection_through_pjrt() {
    let Some((pjrt, _)) = engines() else { return };
    let mut rng = Rng::new(46);
    let mut data = vec![0u8; 64 * 65536];
    rng.fill_bytes(&mut data);
    let base = pjrt.digests_via_pjrt(&data, 65536).unwrap();
    for _ in 0..8 {
        let byte = rng.below(data.len() as u64) as usize;
        let bit = 1u8 << rng.below(8);
        data[byte] ^= bit;
        let got = pjrt.digests_via_pjrt(&data, 65536).unwrap();
        let block = byte / 65536;
        assert_ne!(got[block], base[block], "corruption at byte {byte} missed");
        data[byte] ^= bit; // restore
    }
}

#[test]
fn block_sizes_used_by_plan_are_consistent() {
    let sizes = block_byte_sizes(16 * 4096, 4096, 16);
    assert!(sizes.iter().all(|&s| s == 4096));
    let ragged = block_byte_sizes(10_000, 4096, 3);
    assert_eq!(ragged, vec![4096, 4096, 1808]);
}
