"""Layer-1 Pallas kernels for the XUFS transfer data plane.

Two kernels:

* ``block_digest`` — per-block weighted polynomial checksum over int32 lanes,
  tiled along the block axis with ``BlockSpec`` so each grid step streams one
  ``(BLOCK_B, N)`` tile HBM->VMEM, does a broadcast-multiply + lane reduction
  on the VPU, and writes ``BLOCK_B`` digests back.
* ``dirty_mask`` — elementwise digest compare producing the 0/1 dirty vector.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): this is
reduction/elementwise work, so the target unit is the VPU, not the MXU; the
tiling choice is therefore about VMEM residency of the block tile, not MXU
systolic shape. VMEM per grid step = BLOCK_B*N*4 B (tile) + N*4 B (weights)
+ BLOCK_B*4 B (digests) — kept around ~2 MiB.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which both jax-CPU (tests)
and the rust PJRT client (runtime) execute. Structure, not interpret-mode
wallclock, is what we optimize (see EXPERIMENTS.md §Perf L1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MIX_MUL

# Default block-axis tile. 128 blocks x 4096 lanes x 4 B = 2 MiB per tile:
# fits VMEM (~16 MiB) with headroom for double-buffering the HBM stream.
DEFAULT_BLOCK_B = 128


def _digest_kernel(blocks_ref, weights_ref, out_ref):
    """One grid step: digest BLOCK_B blocks resident in VMEM.

    blocks_ref : int32[BLOCK_B, N] tile in VMEM
    weights_ref: int32[N]          (same for every step; pallas keeps it hot)
    out_ref    : int32[BLOCK_B]
    """
    tile = blocks_ref[...]
    w = weights_ref[...]
    # Broadcast multiply + lane-axis reduction: VPU multiply-accumulate.
    raw = jnp.sum(tile * w[None, :], axis=1, dtype=jnp.int32)
    mixed = raw * jnp.int32(MIX_MUL)
    mixed = mixed ^ jnp.right_shift(mixed, 15)
    out_ref[...] = mixed.astype(jnp.int32)


def block_digest(blocks: jnp.ndarray, weights: jnp.ndarray,
                 block_b: int | None = None) -> jnp.ndarray:
    """Pallas per-block digest. blocks int32[B, N], weights int32[N] -> int32[B].

    ``block_b`` overrides the block-axis tile (must divide B); the default is
    min(B, DEFAULT_BLOCK_B).
    """
    b, n = blocks.shape
    assert weights.shape == (n,), (weights.shape, n)
    if block_b is None:
        block_b = min(b, DEFAULT_BLOCK_B)
    if b % block_b != 0:
        # Fall back to a single tile for ragged small inputs; callers on the
        # hot path always pass power-of-two B.
        block_b = b
    grid = (b // block_b,)
    return pl.pallas_call(
        _digest_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(blocks, weights)


def _dirty_kernel(new_ref, old_ref, out_ref):
    out_ref[...] = (new_ref[...] != old_ref[...]).astype(jnp.int32)


def dirty_mask(digests: jnp.ndarray, old_digests: jnp.ndarray) -> jnp.ndarray:
    """Pallas elementwise digest compare. int32[B] x int32[B] -> int32[B]."""
    (b,) = digests.shape
    assert old_digests.shape == (b,)
    return pl.pallas_call(
        _dirty_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(digests, old_digests)


@functools.lru_cache(maxsize=None)
def vmem_estimate(block_b: int, n: int) -> dict:
    """Static VMEM/roofline estimate for a digest tile (DESIGN.md §Perf L1).

    Not a measurement — interpret mode runs on CPU numpy — but the number the
    design is sized against: tile + weights + digests resident per grid step.
    """
    tile = block_b * n * 4
    weights = n * 4
    out = block_b * 4
    total = tile + weights + out
    # VPU work: 1 multiply + 1 add per lane (MAC), plus O(B) finalization.
    macs = block_b * n
    # HBM traffic: the tile is read once; weights stay resident.
    hbm_bytes = tile + out
    return {
        "vmem_bytes": total,
        "vmem_frac_of_16mib": total / (16 * 1024 * 1024),
        "macs_per_step": macs,
        "hbm_bytes_per_step": hbm_bytes,
        "arith_intensity_macs_per_byte": macs / hbm_bytes,
    }
