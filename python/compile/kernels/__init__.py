from . import checksum, ref  # noqa: F401
