"""Pure-jnp reference oracles for the XUFS data-plane kernels.

These are the CORE correctness signal for the Pallas kernels in
``checksum.py``: pytest (``python/tests/``) asserts bit-exact agreement
between the Pallas implementations and these references across shapes and
dtypes (hypothesis-driven sweeps).

All digest arithmetic is wrapping int32 — XLA integer ops wrap on overflow,
which matches the Rust native fallback (``rust/src/runtime/native.rs``)
bit-for-bit. That bit-exactness is itself asserted by shared test vectors
(see ``python/tests/test_vectors.py`` and rust ``runtime::native`` tests).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Polynomial base for the weighted block digest. Chosen odd (invertible mod
# 2^32) so the weight sequence w^i never collapses to 0 and single-lane
# corruptions always flip the digest.
DIGEST_BASE = 1_000_003

# Finalization multiplier (0x9E3779B9 — golden-ratio avalanche constant —
# as a signed int32, since XLA int32 lanes are signed).
MIX_MUL = -1_640_531_527


def make_weights(n: int, base: int = DIGEST_BASE) -> np.ndarray:
    """w[i] = base**i (mod 2**32), viewed as int32.

    Precomputed host-side (numpy uint64 loop) and fed to the kernel as an
    operand: computing w^i inside the kernel would serialize the lane
    dimension; as an operand it is a broadcast multiply.
    """
    w = np.empty((n,), dtype=np.uint32)
    acc = np.uint64(1)
    b = np.uint64(base)
    mask = np.uint64(0xFFFFFFFF)
    for i in range(n):
        w[i] = np.uint32(acc & mask)
        acc = (acc * b) & mask
    return w.view(np.int32)


def block_digest_ref(blocks: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Reference digest: d[j] = mix(sum_i blocks[j, i] * w[i]).

    blocks : int32[B, N]   (file content widened to int32 lanes)
    weights: int32[N]
    returns: int32[B]
    """
    raw = jnp.sum(blocks * weights[None, :], axis=1, dtype=jnp.int32)
    # Finalization: one multiplicative avalanche round + xor-shift-right, all
    # in wrapping int32. Keeps near-identical blocks from yielding
    # near-identical digests (matters for the dirty-mask compare downstream).
    mixed = raw * jnp.int32(MIX_MUL)
    # arithmetic shift (signed) — mirrored exactly by the rust fallback
    mixed = mixed ^ jnp.right_shift(mixed, 15)
    return mixed.astype(jnp.int32)


def dirty_mask_ref(digests: jnp.ndarray, old_digests: jnp.ndarray) -> jnp.ndarray:
    """dirty[j] = 1 iff the block's digest differs from the cached digest."""
    return (digests != old_digests).astype(jnp.int32)


def stripe_plan_ref(dirty: jnp.ndarray, block_bytes: jnp.ndarray, num_stripes: int) -> jnp.ndarray:
    """Balanced stripe assignment over dirty blocks.

    Blocks are assigned to stripes by the running prefix of dirty bytes so
    each stripe carries ~equal payload. Clean blocks get stripe -1 (not
    shipped). Deterministic and branch-free (cumsum + integer divide) so it
    lowers into the same fused HLO module as the digest kernel.

    dirty       : int32[B] (0/1)
    block_bytes : int32[B] bytes in each block (last block may be short)
    returns     : int32[B] stripe index in [0, num_stripes) or -1
    """
    payload = dirty * block_bytes
    total = jnp.sum(payload)
    # prefix sum of payload *before* each block
    before = jnp.cumsum(payload) - payload
    # ceil-divide total into num_stripes equal spans; guard total == 0
    span = jnp.maximum((total + num_stripes - 1) // num_stripes, 1)
    stripe = jnp.minimum(before // span, num_stripes - 1).astype(jnp.int32)
    return jnp.where(dirty == 1, stripe, jnp.int32(-1))


def transfer_plan_ref(blocks, old_digests, weights, block_bytes, num_stripes: int):
    """Full reference pipeline (digest -> dirty -> stripe plan)."""
    d = block_digest_ref(blocks, weights)
    dirty = dirty_mask_ref(d, old_digests)
    plan = stripe_plan_ref(dirty, block_bytes, num_stripes)
    return d, dirty, plan
