"""AOT compile path: lower the L2 graphs to HLO text for the rust runtime.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out ../artifacts/model.hlo.txt

Emits one HLO text file per (kind, B, N, stripes) variant plus a
``manifest.json`` the rust runtime uses to pick the right artifact for a
transfer's block geometry.

Interchange format is **HLO text**, NOT ``serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate links) rejects with
``proto.id() <= INT_MAX``. The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md and gen_hlo.py.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import make_weights  # noqa: F401  (re-exported for tests)

# Block geometry variants shipped to the rust runtime. N is int32 lanes per
# block: 16384 lanes = 64 KiB, the paper's stripe block size. B is blocks per
# plan invocation; the rust side loops whole files through the largest
# variant that fits and finishes the tail with the small one.
VARIANTS = [
    # (kind, B, N, stripes)
    ("plan", 64, 16384, 12),
    ("plan", 16, 16384, 12),
    ("plan", 16, 1024, 12),   # 4 KiB blocks: metadata/small-file delta path
    ("digest", 64, 16384, 0),
    ("digest", 16, 16384, 0),
    ("digest", 16, 1024, 0),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(kind: str, b: int, n: int, stripes: int) -> str:
    i32 = jnp.int32
    blocks = jax.ShapeDtypeStruct((b, n), i32)
    weights = jax.ShapeDtypeStruct((n,), i32)
    if kind == "plan":
        old = jax.ShapeDtypeStruct((b,), i32)
        bbytes = jax.ShapeDtypeStruct((b,), i32)
        fn = functools.partial(model.transfer_plan, num_stripes=stripes)
        lowered = jax.jit(fn).lower(blocks, old, weights, bbytes)
    elif kind == "digest":
        lowered = jax.jit(model.digest_only).lower(blocks, weights)
    else:
        raise ValueError(f"unknown kind {kind!r}")
    return to_hlo_text(lowered)


def variant_name(kind: str, b: int, n: int, stripes: int) -> str:
    return f"{kind}_{b}x{n}" + (f"_s{stripes}" if kind == "plan" else "")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the default artifact; variants + manifest "
                         "are written next to it")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"digest_base": 1_000_003, "variants": []}
    default_text = None
    for kind, b, n, stripes in VARIANTS:
        name = variant_name(kind, b, n, stripes)
        text = lower_variant(kind, b, n, stripes)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["variants"].append({
            "name": name, "file": f"{name}.hlo.txt", "kind": kind,
            "blocks": b, "lanes": n, "stripes": stripes,
        })
        print(f"wrote {path} ({len(text)} chars)")
        if default_text is None:
            default_text = text

    # The Makefile's stamp artifact: the largest plan variant.
    with open(args.out, "w") as f:
        f.write(default_text)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out} and manifest.json ({len(manifest['variants'])} variants)")


if __name__ == "__main__":
    main()
