"""Layer-2 JAX model: the XUFS transfer-plan compute graph.

``transfer_plan`` is the function the rust coordinator executes on the hot
path (via its AOT-compiled HLO artifact): given the int32 lanes of a file's
blocks, the digests cached from the last sync, and the digest weights, it
returns

  digests   int32[B] — fresh per-block integrity digests (L1 Pallas kernel)
  dirty     int32[B] — 1 where the block changed since the cached digest
  stripe_id int32[B] — balanced stripe assignment for dirty blocks, -1 clean

The stripe planning stays in plain jnp (cumsum + divide): it is O(B) scalar
work that XLA fuses with the dirty-mask; putting it in Pallas would buy
nothing and cost a second kernel launch.

Everything here runs at build time only — ``aot.py`` lowers ``transfer_plan``
once per (B, N, num_stripes) variant to HLO text in ``artifacts/``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import checksum


def transfer_plan(blocks: jnp.ndarray,
                  old_digests: jnp.ndarray,
                  weights: jnp.ndarray,
                  block_bytes: jnp.ndarray,
                  *,
                  num_stripes: int = 12):
    """Digest -> dirty -> balanced stripe plan. See module docstring.

    blocks      : int32[B, N]
    old_digests : int32[B]
    weights     : int32[N]  (make_weights(N); constant per block geometry)
    block_bytes : int32[B]  actual bytes per block (last block may be short)
    """
    digests = checksum.block_digest(blocks, weights)
    dirty = checksum.dirty_mask(digests, old_digests)

    payload = dirty * block_bytes
    total = jnp.sum(payload)
    before = jnp.cumsum(payload) - payload
    span = jnp.maximum((total + num_stripes - 1) // num_stripes, 1)
    stripe = jnp.minimum(before // span, num_stripes - 1).astype(jnp.int32)
    stripe_id = jnp.where(dirty == 1, stripe, jnp.int32(-1))
    return digests, dirty, stripe_id


def digest_only(blocks: jnp.ndarray, weights: jnp.ndarray):
    """Digest-only variant: integrity verification of a fetched file.

    Used by the rust transfer engine to verify striped fetches (no cached
    digests exist yet, so there is no dirty/stripe stage to fuse).
    """
    return (checksum.block_digest(blocks, weights),)
