"""L2 transfer-plan graph: shape contracts and stripe-plan invariants."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def run_plan(blocks, old, w, bbytes, stripes=12):
    d, dirty, plan = model.transfer_plan(
        jnp.asarray(blocks), jnp.asarray(old), jnp.asarray(w),
        jnp.asarray(bbytes), num_stripes=stripes)
    return np.array(d), np.array(dirty), np.array(plan)


def mk(b, n, seed=0):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(-(2**31), 2**31, size=(b, n), dtype=np.int64).astype(np.int32)
    w = ref.make_weights(n)
    bbytes = np.full((b,), n * 4, dtype=np.int32)
    return rng, blocks, w, bbytes


def test_all_clean_no_stripes():
    _, blocks, w, bbytes = mk(16, 64)
    d = np.array(ref.block_digest_ref(jnp.asarray(blocks), jnp.asarray(w)))
    d2, dirty, plan = run_plan(blocks, d, w, bbytes)
    np.testing.assert_array_equal(d2, d)
    assert (dirty == 0).all()
    assert (plan == -1).all()


def test_all_dirty_balanced():
    b = 48
    _, blocks, w, bbytes = mk(b, 32, seed=3)
    old = np.zeros((b,), dtype=np.int32)  # everything differs
    _, dirty, plan = run_plan(blocks, old, w, bbytes, stripes=12)
    assert (dirty == 1).all()
    assert plan.min() >= 0 and plan.max() <= 11
    # balanced: every stripe carries b/12 = 4 equal-size blocks
    counts = np.bincount(plan, minlength=12)
    assert counts.max() - counts.min() <= 1, counts


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 64), stripes=st.integers(1, 12),
       seed=st.integers(0, 2**31 - 1))
def test_plan_invariants(b, stripes, seed):
    rng, blocks, w, bbytes = mk(b, 16, seed=seed)
    old = np.array(ref.block_digest_ref(jnp.asarray(blocks), jnp.asarray(w)))
    flip = rng.random(b) < 0.4
    old[flip] ^= 1
    _, dirty, plan = run_plan(blocks, old, w, bbytes, stripes=stripes)
    # dirty exactly where flipped
    np.testing.assert_array_equal(dirty, flip.astype(np.int32))
    # clean blocks unassigned; dirty blocks assigned within range
    assert (plan[dirty == 0] == -1).all()
    assert ((plan[dirty == 1] >= 0) & (plan[dirty == 1] < stripes)).all()
    # stripe ids are non-decreasing over dirty blocks (prefix-sum assignment)
    dp = plan[dirty == 1]
    assert (np.diff(dp) >= 0).all()


def test_plan_matches_ref_pipeline():
    b, n = 32, 128
    rng, blocks, w, bbytes = mk(b, n, seed=11)
    old = np.array(ref.block_digest_ref(jnp.asarray(blocks), jnp.asarray(w)))
    old[::3] += 7
    want = ref.transfer_plan_ref(jnp.asarray(blocks), jnp.asarray(old),
                                 jnp.asarray(w), jnp.asarray(bbytes), 12)
    got = run_plan(blocks, old, w, bbytes, stripes=12)
    for g, wnt in zip(got, want):
        np.testing.assert_array_equal(g, np.array(wnt))


def test_short_tail_block_weighting():
    """A short final block (fewer bytes) shifts stripe spans accordingly."""
    b = 8
    _, blocks, w, _ = mk(b, 16, seed=5)
    bbytes = np.full((b,), 64, dtype=np.int32)
    bbytes[-1] = 4  # short tail
    old = np.zeros((b,), dtype=np.int32)
    _, dirty, plan = run_plan(blocks, old, w, bbytes, stripes=2)
    assert (dirty == 1).all()
    # total payload = 7*64+4 = 452, span = 226 -> first 4 blocks (256 > 226
    # boundary after block 3) split roughly half/half
    assert plan[0] == 0 and plan[-1] == 1


def test_digest_only_variant():
    b, n = 16, 64
    _, blocks, w, _ = mk(b, n, seed=21)
    (d,) = model.digest_only(jnp.asarray(blocks), jnp.asarray(w))
    want = ref.block_digest_ref(jnp.asarray(blocks), jnp.asarray(w))
    np.testing.assert_array_equal(np.array(d), np.array(want))


@pytest.mark.parametrize("stripes", [1, 2, 12])
def test_single_dirty_block_goes_to_stripe_zero(stripes):
    b = 16
    _, blocks, w, bbytes = mk(b, 16, seed=8)
    old = np.array(ref.block_digest_ref(jnp.asarray(blocks), jnp.asarray(w)))
    old[9] ^= 1
    _, dirty, plan = run_plan(blocks, old, w, bbytes, stripes=stripes)
    assert dirty.sum() == 1
    assert plan[9] == 0
