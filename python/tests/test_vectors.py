"""Golden cross-language test vectors.

The SAME vectors are asserted by the rust native fallback
(``rust/src/runtime/native.rs`` unit tests). If either side drifts, the
bit-exact HLO<->native equivalence the transfer engine relies on is broken.
Keep the constants in sync with the rust test (they are generated from
``ref.py`` and frozen here).
"""

import numpy as np
import jax.numpy as jnp

from compile.kernels import ref

GOLDEN_B, GOLDEN_N = 4, 8

# blocks[j][i] = (j*1000003 + i*7 + 1) mod 2^32, viewed as int32
GOLDEN_WEIGHTS = [1, 1000003, -721379959, 583896283,
                  1525764945, -429739981, 272515929, 1071616587]
GOLDEN_DIGESTS = [19047297, 1229507876, 1855012728, 644638899]


def golden_blocks() -> np.ndarray:
    return np.array(
        [[(j * 1000003 + i * 7 + 1) & 0xFFFFFFFF for i in range(GOLDEN_N)]
         for j in range(GOLDEN_B)],
        dtype=np.uint32,
    ).view(np.int32)


def test_golden_weights():
    w = ref.make_weights(GOLDEN_N)
    assert [int(x) for x in w] == GOLDEN_WEIGHTS


def test_golden_digests():
    blocks = golden_blocks()
    w = ref.make_weights(GOLDEN_N)
    d = ref.block_digest_ref(jnp.asarray(blocks), jnp.asarray(w))
    assert [int(x) for x in np.array(d)] == GOLDEN_DIGESTS


def test_golden_digests_pallas():
    from compile.kernels import checksum
    blocks = golden_blocks()
    w = ref.make_weights(GOLDEN_N)
    d = checksum.block_digest(jnp.asarray(blocks), jnp.asarray(w))
    assert [int(x) for x in np.array(d)] == GOLDEN_DIGESTS
