"""Pallas kernel vs pure-jnp reference: the core L1 correctness signal.

Hypothesis sweeps shapes and contents; every comparison is bit-exact
(integer arithmetic — no tolerance).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import checksum, ref


def rand_blocks(rng: np.random.Generator, b: int, n: int) -> np.ndarray:
    return rng.integers(-(2**31), 2**31, size=(b, n), dtype=np.int64).astype(np.int32)


@pytest.mark.parametrize("b,n", [(1, 1), (1, 128), (4, 64), (16, 1024),
                                 (64, 256), (128, 32), (3, 17), (7, 129)])
def test_digest_matches_ref(b, n):
    rng = np.random.default_rng(b * 1000 + n)
    blocks = rand_blocks(rng, b, n)
    w = ref.make_weights(n)
    got = checksum.block_digest(jnp.asarray(blocks), jnp.asarray(w))
    want = ref.block_digest_ref(jnp.asarray(blocks), jnp.asarray(w))
    np.testing.assert_array_equal(np.array(got), np.array(want))


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 48), n=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
def test_digest_matches_ref_hypothesis(b, n, seed):
    rng = np.random.default_rng(seed)
    blocks = rand_blocks(rng, b, n)
    w = ref.make_weights(n)
    got = checksum.block_digest(jnp.asarray(blocks), jnp.asarray(w))
    want = ref.block_digest_ref(jnp.asarray(blocks), jnp.asarray(w))
    np.testing.assert_array_equal(np.array(got), np.array(want))


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_dirty_mask_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    new = rng.integers(-(2**31), 2**31, size=(b,), dtype=np.int64).astype(np.int32)
    old = new.copy()
    flip = rng.random(b) < 0.3
    old[flip] ^= 1
    got = checksum.dirty_mask(jnp.asarray(new), jnp.asarray(old))
    want = ref.dirty_mask_ref(jnp.asarray(new), jnp.asarray(old))
    np.testing.assert_array_equal(np.array(got), np.array(want))
    np.testing.assert_array_equal(np.array(got), flip.astype(np.int32))


def test_digest_sensitive_to_single_lane():
    """Flipping any single lane must flip the block digest (integrity)."""
    rng = np.random.default_rng(7)
    b, n = 4, 64
    blocks = rand_blocks(rng, b, n)
    w = ref.make_weights(n)
    base = np.array(checksum.block_digest(jnp.asarray(blocks), jnp.asarray(w)))
    for _ in range(20):
        j = rng.integers(0, b)
        i = rng.integers(0, n)
        mutated = blocks.copy()
        mutated[j, i] ^= np.int32(1 << int(rng.integers(0, 31)))
        d = np.array(checksum.block_digest(jnp.asarray(mutated), jnp.asarray(w)))
        assert d[j] != base[j], f"digest missed corruption at ({j},{i})"
        # other blocks unaffected
        others = np.arange(b) != j
        np.testing.assert_array_equal(d[others], base[others])


def test_digest_order_sensitive():
    """Swapping two lanes must change the digest (positional weights)."""
    n = 16
    w = ref.make_weights(n)
    a = np.arange(1, n + 1, dtype=np.int32)[None, :]
    swapped = a.copy()
    swapped[0, 0], swapped[0, 5] = swapped[0, 5], swapped[0, 0]
    d0 = np.array(checksum.block_digest(jnp.asarray(a), jnp.asarray(w)))
    d1 = np.array(checksum.block_digest(jnp.asarray(swapped), jnp.asarray(w)))
    assert d0[0] != d1[0]


@pytest.mark.parametrize("block_b", [1, 2, 4, 8])
def test_digest_tiling_invariant(block_b):
    """Result must not depend on the BlockSpec tile size."""
    rng = np.random.default_rng(99)
    b, n = 8, 128
    blocks = rand_blocks(rng, b, n)
    w = ref.make_weights(n)
    want = ref.block_digest_ref(jnp.asarray(blocks), jnp.asarray(w))
    got = checksum.block_digest(jnp.asarray(blocks), jnp.asarray(w), block_b=block_b)
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_weights_deterministic_and_nonzero():
    w = ref.make_weights(4096)
    w2 = ref.make_weights(4096)
    np.testing.assert_array_equal(w, w2)
    assert w[0] == 1
    # odd base => all weights odd => never zero
    assert (np.array(w, dtype=np.int64) % 2 == 1).all()


def test_vmem_estimate_within_budget():
    est = checksum.vmem_estimate(checksum.DEFAULT_BLOCK_B, 16384)
    assert est["vmem_bytes"] < 16 * 1024 * 1024 * 0.6, est
    assert est["arith_intensity_macs_per_byte"] > 0.2
