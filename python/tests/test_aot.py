"""AOT lowering: HLO text artifacts have the expected interface contract.

The rust runtime parses these artifacts with xla_extension 0.5.1's HLO text
parser; these tests pin the properties that contract depends on (parameter
count/order, ENTRY signature, int32 shapes, tuple result) without needing
the rust toolchain.
"""

import json
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def plan_hlo():
    return aot.lower_variant("plan", 16, 1024, 12)


@pytest.fixture(scope="module")
def digest_hlo():
    return aot.lower_variant("digest", 16, 1024, 0)


def entry_line(hlo: str) -> str:
    """The entry_computation_layout on the HloModule header line carries the
    signature (the ENTRY line itself is just a name)."""
    first = hlo.splitlines()[0]
    assert first.startswith("HloModule") and "entry_computation_layout" in first
    return first


def test_plan_entry_signature(plan_hlo):
    line = entry_line(plan_hlo)
    # 4 params: blocks[16,1024], old[16], weights[1024], block_bytes[16]
    assert "s32[16,1024]" in line
    assert line.count("s32[16]") >= 2
    assert "s32[1024]" in line
    # tuple of 3 results
    assert re.search(r"->\s*\(s32\[16\].*s32\[16\].*s32\[16\]", line), line


def test_digest_entry_signature(digest_hlo):
    line = entry_line(digest_hlo)
    assert "s32[16,1024]" in line and "s32[1024]" in line
    assert re.search(r"->\s*\(s32\[16\]", line), line


def test_no_custom_calls(plan_hlo, digest_hlo):
    """interpret=True must lower to plain HLO — a Mosaic custom-call would
    be unexecutable on the CPU PJRT client the rust runtime uses."""
    for hlo in (plan_hlo, digest_hlo):
        assert "custom-call" not in hlo, "found custom-call in lowered HLO"


def test_variant_names_unique():
    names = [aot.variant_name(*v) for v in aot.VARIANTS]
    assert len(names) == len(set(names))


def test_manifest_roundtrip(tmp_path):
    """End-to-end: main() writes parseable artifacts + manifest."""
    import sys
    from unittest import mock
    out = tmp_path / "model.hlo.txt"
    argv = ["aot", "--out", str(out)]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["digest_base"] == 1_000_003
    assert len(manifest["variants"]) == len(aot.VARIANTS)
    for v in manifest["variants"]:
        text = (tmp_path / v["file"]).read_text()
        assert text.startswith("HloModule"), v["file"]
        assert out.exists()
