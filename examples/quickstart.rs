//! Quickstart: mount a private name space, read/write across the WAN,
//! watch callback invalidation and disconnected operation work.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use xufs::client::{MetaBatchOp, OpenFlags, ServerLink, Vfs};
use xufs::config::XufsConfig;
use xufs::coordinator::SimWorld;
use xufs::metrics::names;
use xufs::simnet::VirtualTime;

fn main() {
    // 1. a deployment: the user's personal system (home space) + a
    //    TeraGrid-site client over the calibrated 32 ms / 30 Gbps WAN
    let mut cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    cfg.cache.localized_dirs = vec!["/home/alice/scratch".into()];
    let mut world = SimWorld::new(cfg);

    // the user's laptop has a project directory
    world.home(|s| {
        let t = VirtualTime::ZERO;
        s.home_mut().mkdir_p("/home/alice/proj", t).unwrap();
        s.home_mut().write("/home/alice/proj/input.dat", &vec![42u8; 8 << 20], t).unwrap();
        s.home_mut().write("/home/alice/proj/notes.txt", b"wide-area fs notes\n", t).unwrap();
    });

    // 2. USSH login + mount (auth handshake, callback registration)
    let mut client = world.mount("/home/alice").expect("mount");
    println!(
        "mounted /home/alice  (digest engine: {})",
        if world.engine.is_pjrt() { "PJRT artifacts" } else { "native" }
    );

    // 3. first open pulls the file whole, striped, into cache space
    let t0 = client.now();
    let n = client.scan_file("/home/alice/proj/input.dat", 1 << 20).unwrap();
    println!(
        "cold read  : {n} bytes in {:.2}s (striped WAN fetch + cache install)",
        client.now().saturating_sub(t0).as_secs()
    );

    // 4. re-reads never touch the WAN
    let t1 = client.now();
    client.scan_file("/home/alice/proj/input.dat", 1 << 20).unwrap();
    println!(
        "warm read  : same file in {:.3}s (cache-space local)",
        client.now().saturating_sub(t1).as_secs()
    );

    // 5. writes aggregate in a shadow file; close ships them home
    client.write_file("/home/alice/proj/results.txt", b"energy = -42.7\n", 4096).unwrap();
    let home_copy = world.home(|s| s.home().read("/home/alice/proj/results.txt").unwrap().to_vec());
    println!("writeback  : results.txt at home == {:?}", String::from_utf8_lossy(&home_copy).trim());

    // 6. batched metadata (Vfs v2): N meta-ops, one compound WAN round
    //    trip, per-op status
    let results = client
        .batch(&[
            MetaBatchOp::Mkdir { path: "/home/alice/proj/figs".into() },
            MetaBatchOp::Stat { path: "/home/alice/proj/input.dat".into() },
            MetaBatchOp::Stat { path: "/home/alice/proj/notes.txt".into() },
        ])
        .unwrap();
    println!(
        "batch      : {} meta-ops OK, {} compound round trips so far",
        results.iter().filter(|r| !r.is_err()).count(),
        client.metrics().counter(names::COMPOUND_RPCS)
    );

    // 7. the user edits a file on the laptop -> callback invalidates the
    //    cached copy; next open re-fetches
    world.home(|s| {
        s.local_write("/home/alice/proj/notes.txt", b"edited at home!\n", VirtualTime::from_secs(100.0))
            .unwrap()
    });
    let fd = client.open("/home/alice/proj/notes.txt", OpenFlags::rdonly()).unwrap();
    let mut fresh = [0u8; 64];
    let n = client.read(fd, &mut fresh).unwrap();
    client.close(fd).unwrap();
    println!(
        "callback   : cached copy invalidated, reopened -> {:?}",
        String::from_utf8_lossy(&fresh[..n]).trim()
    );

    // 8. localized directories never ship home (raw simulation output)
    client.write_file("/home/alice/scratch/raw_output.bin", &vec![7u8; 4 << 20], 1 << 20).unwrap();
    let at_home = world.home(|s| s.home().exists("/home/alice/scratch/raw_output.bin"));
    println!("localized  : 4 MiB raw output stayed at the site (at home: {at_home})");

    // 9. disconnected operation: pull the cable, keep working
    client.link_mut().set_network(false);
    let n = client.scan_file("/home/alice/proj/input.dat", 1 << 20).unwrap();
    client.write_file("/home/alice/proj/offline_note.txt", b"written offline", 4096).unwrap();
    println!(
        "offline    : read {n} cached bytes, queued {} ops while disconnected",
        client.queue_len()
    );
    client.link_mut().set_network(true);
    client.link_mut().reconnect().unwrap();
    client.fsync().unwrap();
    let landed = world.home(|s| s.home().exists("/home/alice/proj/offline_note.txt"));
    println!("reconnect  : queue replayed, offline_note.txt at home: {landed}");

    println!("\nmetrics: {}", client.metrics().to_json());
}
