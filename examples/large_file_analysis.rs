//! Large-file analysis scenario (paper §4.3): `wc -l` over a large
//! simulation output stored at the home space, plus the Table 2
//! comparison against copying the file first with TGCP or SCP.
//!
//! ```text
//! cargo run --release --example large_file_analysis          # 1 GiB
//! QUICK=1 cargo run --release --example large_file_analysis  # 256 MiB
//! ```

use xufs::bench::run_fig5_table2;
use xufs::config::XufsConfig;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let size: u64 = if quick { 256 << 20 } else { 1 << 30 };
    let cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    println!(
        "Scanning a {} MiB file across the WAN, 5 consecutive runs…",
        size >> 20
    );
    let (fig5, table2) = run_fig5_table2(&cfg, 5, size);
    fig5.print();
    table2.print();
    println!("\nXUFS pays the striped fetch once; every re-analysis is local.");
    println!("GPFS-WAN re-reads blocks over the WAN on every run (no whole-file cache).");
}
