//! End-to-end driver: the paper's full computational-science workflow
//! (§2.1) on a realistic scratch population, across BOTH deployments —
//! the calibrated WAN simulation and the real-TCP protocol stack — with
//! the AOT (PJRT) digest artifacts on the transfer path.
//!
//! Workflow: 1) develop code at home, 2) mount at the site and build it,
//! 3) stage input data, 4) "run the simulation" (reads inputs, writes raw
//! output into a *localized* dir), 5) analyze (scan outputs, write a
//! summary), 6) summary lands back home, 7) raw output never crosses the
//! WAN. Headline metrics are printed at each stage; EXPERIMENTS.md §E2E
//! records a reference run.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_teragrid
//! ```

use std::sync::{Arc, Mutex};
use std::time::Instant;

use xufs::auth::{Authenticator, KeyPair};
use xufs::baselines::{Scp, Tgcp};
use xufs::client::{OpenFlags, Vfs, XufsClient};
use xufs::config::XufsConfig;
use xufs::coordinator::net::{TcpLink, TcpServer};
use xufs::coordinator::SimWorld;
use xufs::homefs::FileStore;
use xufs::metrics::{names, Metrics};
use xufs::runtime::DigestEngine;
use xufs::server::FileServer;
use xufs::simnet::{RealClock, SimClock, VirtualTime, Wan};
use xufs::util::stats;
use xufs::util::Rng;
use xufs::vdisk::DiskModel;
use xufs::workload::{buildtree, largefile, sizedist};

const MIB: u64 = 1 << 20;

fn main() {
    println!("=== XUFS end-to-end: TeraGrid workflow ===\n");
    phase_sim();
    phase_tcp();
    println!("\n=== e2e complete ===");
}

/// Phase 1: the full workflow on the calibrated WAN model (simulated
/// seconds match the paper's testbed scale).
fn phase_sim() {
    println!("--- phase 1: simulated 32 ms / 30 Gbps WAN (virtual time) ---");
    let mut cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    cfg.cache.localized_dirs = vec!["/home/sci/runs".into()];
    let mut world = SimWorld::new(cfg.clone());
    println!(
        "digest engine: {}",
        if world.engine.is_pjrt() { "PJRT (AOT artifacts)" } else { "native fallback" }
    );

    // 1) develop at home: the source tree + input data live on the laptop
    let spec = buildtree::BuildSpec::default();
    world.home(|s| {
        buildtree::generate_tree(&mut s.home_mut(), "/home/sci/code", &spec, 7).unwrap();
        let input = largefile::text_content(64 << 20, 96, 11);
        s.home_mut().mkdir_p("/home/sci/data", VirtualTime::ZERO).unwrap();
        s.home_mut().write("/home/sci/data/input.dat", &input, VirtualTime::ZERO).unwrap();
    });

    // 2) mount at the site and build
    let mut c = world.mount("/home/sci").expect("mount");
    let t0 = c.now();
    let build = buildtree::build(&mut c, "/home/sci/code", &spec).unwrap();
    println!(
        "build      : {} sources compiled in {:.1}s (prefetched {} small files)",
        build.sources_compiled,
        build.secs,
        c.metrics().counter(names::PREFETCH_FILES)
    );

    // 3) stage input: first read pulls it into cache space, striped
    let t1 = c.now();
    let n = c.scan_file("/home/sci/data/input.dat", MIB as usize).unwrap();
    println!(
        "stage input: {} in {:.1}s (striped cold fetch)",
        stats::human_bytes(n),
        c.now().saturating_sub(t1).as_secs()
    );

    // 4) the "simulation": re-reads input (cache-local), writes raw
    //    output into the localized dir — it must never cross the WAN
    let t2 = c.now();
    let mut rng = Rng::new(13);
    c.scan_file("/home/sci/data/input.dat", MIB as usize).unwrap();
    let mut raw = vec![0u8; (128 << 20) as usize];
    rng.fill_bytes(&mut raw);
    c.write_file("/home/sci/runs/raw_000.bin", &raw, MIB as usize).unwrap();
    println!(
        "simulate   : read input warm + wrote {} raw output in {:.1}s (localized)",
        stats::human_bytes(raw.len() as u64),
        c.now().saturating_sub(t2).as_secs()
    );

    // 5) analysis: scan the raw output locally, write a small summary
    let t3 = c.now();
    let (lines, _) = largefile::wc_l(&mut c, "/home/sci/runs/raw_000.bin", MIB as usize).unwrap();
    let summary = format!("raw lines: {lines}\nenergy: -42.7\n");
    c.write_file("/home/sci/data/summary.txt", summary.as_bytes(), 4096).unwrap();
    println!("analyze    : scanned raw output + wrote summary in {:.1}s", c.now().saturating_sub(t3).as_secs());

    // 6) the summary landed at home; 7) the raw output did not
    let (summary_home, raw_home) = world.home(|s| {
        (
            s.home().exists("/home/sci/data/summary.txt"),
            s.home().exists("/home/sci/runs/raw_000.bin"),
        )
    });
    assert!(summary_home && !raw_home);
    println!("result     : summary at home: {summary_home}; raw at home: {raw_home} (correct)");

    let wan = world.wan.stats();
    println!(
        "WAN totals : {} moved, {} rpcs; workflow wall (virtual): {:.1}s",
        stats::human_bytes(wan.bytes),
        wan.rpcs,
        c.now().saturating_sub(t0).as_secs()
    );

    // what the pre-XUFS workflow would have cost: SCP the inputs + code
    // down and the summary back
    let clock = Arc::new(SimClock::new());
    let wan2 = Arc::new(Wan::new(cfg.wan.clone(), (*clock).clone()));
    let scp = Scp::new(wan2.clone(), clock.clone(), DiskModel::new(cfg.disk.cache_bps, cfg.disk.cache_op_s), XufsConfig::scp_cipher_bps());
    let scp_secs = scp.copy(64 << 20);
    let tgcp = Tgcp::new(wan2, clock, DiskModel::new(cfg.disk.cache_bps, cfg.disk.cache_op_s), cfg.stripe.clone());
    let tgcp_secs = tgcp.copy(64 << 20);
    println!("baseline   : staging the 64 MiB input alone = {scp_secs:.0}s via SCP, {tgcp_secs:.1}s via TGCP");

    // Table-1-shaped scratch population sanity: the site sees the paper's
    // byte skew (big files dominate bytes)
    let sizes = sizedist::generate_sizes(&sizedist::SizeDistParams { scale: 0.0005 }, 3);
    let census = sizedist::census(&sizes);
    let m1 = &census.rows[5];
    println!(
        "population : {} files, {:.1} GB generated; >1M files carry {:.1}% of bytes (paper: 98.5%)",
        census.total_files, census.total_gb, m1.byte_pct
    );
}

/// Phase 2: the identical client/server logic over real TCP sockets —
/// USSH handshake, striped range fetches, push callbacks, crash recovery —
/// with real wall-clock latency/throughput numbers.
fn phase_tcp() {
    println!("\n--- phase 2: real TCP on localhost (wall-clock) ---");
    let metrics = Metrics::new();
    let engine = Arc::new(
        DigestEngine::from_artifacts("artifacts", metrics.clone())
            .unwrap_or_else(|_| DigestEngine::native(metrics.clone())),
    );
    let mut rng = Rng::new(99);
    let pair = KeyPair::generate(&mut rng, VirtualTime::ZERO, 3600.0);

    // the user's personal file server
    let mut home = FileStore::default();
    home.mkdir_p("/home/sci", VirtualTime::ZERO).unwrap();
    let mut payload = vec![0u8; (32 * MIB) as usize];
    rng.fill_bytes(&mut payload);
    home.write("/home/sci/big.bin", &payload, VirtualTime::ZERO).unwrap();
    for i in 0..20 {
        home.write(&format!("/home/sci/small{i:02}.txt"), format!("note {i}\n").as_bytes(), VirtualTime::ZERO)
            .unwrap();
    }
    let cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    let server = Arc::new(FileServer::new(
        home,
        DiskModel::new(1e12, 0.0), // real I/O is real; no modeled delay
        engine.clone(),
        64 * 1024,
        30.0,
        cfg.server.shards,
        metrics.clone(),
    ));
    let auth = Arc::new(Mutex::new(Authenticator::new(pair.clone(), 5)));
    let tcp = TcpServer::spawn(server.clone(), auth, metrics.clone()).expect("bind");
    println!("server     : listening on {}", tcp.addr);
    let link = TcpLink::connect(tcp.addr, pair.clone(), cfg.clone(), 1, "/home/sci", metrics.clone())
        .expect("connect");
    let clock = Arc::new(RealClock::new());
    let mut client = XufsClient::new(link, cfg.clone(), engine.clone(), clock, "/home/sci", metrics.clone());

    // striped fetch throughput (12 real connections)
    let w0 = Instant::now();
    let n = client.scan_file("/home/sci/big.bin", MIB as usize).unwrap();
    let cold = w0.elapsed().as_secs_f64();
    println!(
        "cold fetch : {} over {} stripes in {:.3}s  ({:.0} MiB/s, digest-verified)",
        stats::human_bytes(n),
        cfg.stripe.max_stripes,
        cold,
        stats::mib_per_sec(n, cold)
    );
    let w1 = Instant::now();
    client.scan_file("/home/sci/big.bin", MIB as usize).unwrap();
    println!("warm read  : {:.3}s (cache-local)", w1.elapsed().as_secs_f64());

    // small-op latency distribution over the real socket
    let mut lat = Vec::new();
    for i in 0..20 {
        let w = Instant::now();
        client.scan_file(&format!("/home/sci/small{i:02}.txt"), 4096).unwrap();
        lat.push(w.elapsed().as_secs_f64() * 1e3);
    }
    println!(
        "small files: 20 fetched; latency p50 {:.2} ms, p99 {:.2} ms",
        stats::percentile(&lat, 50.0),
        stats::percentile(&lat, 99.0)
    );

    // write-back over the real protocol + cross-check at the server
    client.write_file("/home/sci/from_site.txt", b"written via TCP link", 4096).unwrap();
    let ok = server.home().read("/home/sci/from_site.txt").unwrap() == b"written via TCP link";
    println!("writeback  : applied at the server over TCP: {ok}");

    // push-mode callback: a home-side edit invalidates the cached copy
    server
        .local_write("/home/sci/small00.txt", b"changed under you\n", VirtualTime::from_secs(1.0))
        .unwrap();
    std::thread::sleep(std::time::Duration::from_millis(100)); // callback pump
    let fd = client.open("/home/sci/small00.txt", OpenFlags::rdonly()).unwrap();
    let mut fresh = [0u8; 64];
    let n = client.read(fd, &mut fresh).unwrap();
    client.close(fd).unwrap();
    println!(
        "callback   : push invalidation delivered; reopen sees {:?}",
        String::from_utf8_lossy(&fresh[..n]).trim()
    );

    // crash recovery over TCP: queue ops offline-style, recover, replay
    let snapshot = client.cache_store_snapshot();
    drop(client);
    let link2 = TcpLink::connect(tcp.addr, pair, cfg.clone(), 2, "/home/sci", metrics.clone()).unwrap();
    let (c2, corrupt) = XufsClient::recover(
        link2,
        cfg,
        engine,
        Arc::new(RealClock::new()),
        "/home/sci",
        snapshot,
        metrics.clone(),
    );
    println!("recovery   : client rebuilt from cache space (corrupt entries: {corrupt}, queue: {})", c2.queue_len());
    drop(c2);

    println!("metrics    : {}", metrics.to_json());
}
