//! Source-build scenario (paper §4.2): a scientist keeps their code on
//! the laptop and builds it at a TeraGrid site through XUFS. Compares
//! consecutive clean-make times against GPFS-WAN and the local FS, and
//! shows what the parallel pre-fetch buys.
//!
//! ```text
//! cargo run --release --example build_tree
//! ```

use xufs::bench::{run_ablation_prefetch, run_fig4};
use xufs::config::XufsConfig;

fn main() {
    let cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    println!("Building a 24-file / ~12 kLoC / 5-subdir C tree across the WAN…");
    run_fig4(&cfg, 5).print();
    run_ablation_prefetch(&cfg).print();
    println!("\nThe first XUFS run pays directory materialization + pre-fetch;");
    println!("later runs compile from cache and only ship the .o files home.");
}
